//! Block-granularity prefix trie over token ids: find the longest
//! cached prefix of an incoming prompt, adopt its blocks, and publish a
//! freshly prefilled prompt for the next request to reuse.

use super::block::{BlockData, BlockId, BlockPool};
use std::collections::HashMap;
use std::sync::Arc;

/// One adopted block: its pool id (refcount already bumped) and the
/// shared payload to read rows from.
pub struct AdoptedBlock {
    pub id: BlockId,
    pub data: Arc<BlockData>,
}

/// Result of [`PrefixIndex::lookup`]: `rows` cached rows adopted per
/// layer stream. `rows == 0` (empty `layers`) is a miss. The caller owns
/// the references — seed a session with them
/// ([`crate::model::Transformer::new_session_from_prefix`]) or release
/// them.
pub struct PrefixMatch {
    /// Prompt rows covered by the adopted blocks (block-aligned full
    /// chunks plus an optional partial-tail span).
    pub rows: usize,
    /// Per layer: the adopted K-block chain and V-block chain.
    pub layers: Vec<(Vec<AdoptedBlock>, Vec<AdoptedBlock>)>,
}

impl PrefixMatch {
    /// A miss: prefill must start from token zero.
    pub fn empty() -> Self {
        Self {
            rows: 0,
            layers: Vec::new(),
        }
    }

    /// Full blocks per stream in this match (the tail span, if any, is
    /// copy-on-written by its adopter and so does not reduce the
    /// adopter's new-block budget).
    pub fn full_blocks(&self, block_tokens: usize) -> usize {
        self.rows / block_tokens
    }

    /// Release every adopted reference back to `pool` — for a match the
    /// caller decided not to use. (Seeding a session instead *transfers*
    /// the references: the session's paged stores release them on drop.)
    pub fn release(self, pool: &BlockPool) {
        for (ks, vs) in self.layers {
            for b in ks.into_iter().chain(vs) {
                pool.release(b.id);
            }
        }
    }
}

/// A published partial prompt tail hanging off a trie node: fewer than
/// `block_tokens` tokens, shared so an identical continuation can adopt
/// the rows and copy-on-write when it diverges.
struct Tail {
    tokens: Vec<u16>,
    /// Per layer (K block, V block) — index-held references.
    layers: Vec<(BlockId, BlockId)>,
    last_use: u64,
}

struct Node {
    parent: usize,
    /// This node's chunk (empty for the root).
    key: Vec<u16>,
    children: HashMap<Vec<u16>, usize>,
    /// Per layer (K block, V block) for this chunk — index-held
    /// references (empty for the root).
    layers: Vec<(BlockId, BlockId)>,
    tails: Vec<Tail>,
    last_use: u64,
}

/// Trie over token ids at block granularity. Each depth-`k` node is one
/// published full block per (layer, K|V) stream covering prompt rows
/// `[(k-1)·bs, k·bs)`; matching is exact chunk equality, so a lookup
/// adopts only KV that is bit-identical to what prefill would recompute
/// (causal attention: prefix KV depends on the prefix tokens alone).
/// The index holds its own pool references, so published prefixes
/// survive session retirement until [`Self::evict_lru`] reclaims them.
///
/// Not internally synchronized — the serving backend wraps it in a
/// `Mutex` and takes it only at admission/publish boundaries; decode
/// reads never touch the index.
pub struct PrefixIndex {
    block_tokens: usize,
    n_layers: usize,
    nodes: Vec<Option<Node>>,
    free_nodes: Vec<usize>,
    clock: u64,
}

impl PrefixIndex {
    pub fn new(block_tokens: usize, n_layers: usize) -> Self {
        assert!(block_tokens >= 1);
        Self {
            block_tokens,
            n_layers,
            nodes: vec![Some(Node {
                parent: usize::MAX,
                key: Vec::new(),
                children: HashMap::new(),
                layers: Vec::new(),
                tails: Vec::new(),
                last_use: 0,
            })],
            free_nodes: Vec::new(),
            clock: 0,
        }
    }

    fn node(&self, id: usize) -> &Node {
        self.nodes[id].as_ref().expect("live node")
    }

    fn node_mut(&mut self, id: usize) -> &mut Node {
        self.nodes[id].as_mut().expect("live node")
    }

    /// Longest cached prefix of `prompt`, *without* adopting anything —
    /// what admission costing uses. Matching is capped at
    /// `prompt.len() - 1`: at least one token is always left for the
    /// suffix prefill to produce first-token logits from.
    pub fn match_rows(&self, prompt: &[u16]) -> usize {
        let (chain, tail) = self.walk(prompt);
        chain.len() * self.block_tokens + tail.map_or(0, |(_, rows)| rows)
    }

    /// Walk the trie: matched node chain (full blocks) plus the best
    /// partial-tail match `(tail index in the last matched node, rows)`.
    fn walk(&self, prompt: &[u16]) -> (Vec<usize>, Option<(usize, usize)>) {
        let bs = self.block_tokens;
        let max_rows = prompt.len().saturating_sub(1);
        let mut chain = Vec::new();
        let mut node = 0usize;
        for chunk in prompt.chunks_exact(bs) {
            if (chain.len() + 1) * bs > max_rows {
                break;
            }
            match self.node(node).children.get(chunk) {
                Some(&child) => {
                    node = child;
                    chain.push(child);
                }
                None => break,
            }
        }
        let matched = chain.len() * bs;
        let remaining = &prompt[matched..];
        let budget = max_rows - matched;
        let mut best: Option<(usize, usize)> = None;
        for (ti, tail) in self.node(node).tails.iter().enumerate() {
            let mut rows = 0;
            for (a, b) in tail.tokens.iter().zip(remaining.iter()) {
                if a != b || rows >= budget {
                    break;
                }
                rows += 1;
            }
            let beats = match best {
                None => rows > 0,
                Some((_, r)) => rows > r,
            };
            if beats {
                best = Some((ti, rows));
            }
        }
        (chain, best)
    }

    /// Match `prompt`'s longest cached block-aligned prefix (plus a
    /// stored partial tail), bump refcounts on every matched block, and
    /// return the adopted chains. The caller prefills only the suffix.
    pub fn lookup(&mut self, prompt: &[u16], pool: &BlockPool) -> PrefixMatch {
        self.clock += 1;
        let clock = self.clock;
        let (chain, tail) = self.walk(prompt);
        if chain.is_empty() && tail.is_none() {
            return PrefixMatch::empty();
        }
        let last = chain.last().copied().unwrap_or(0);
        let mut layers: Vec<(Vec<AdoptedBlock>, Vec<AdoptedBlock>)> =
            (0..self.n_layers).map(|_| (Vec::new(), Vec::new())).collect();
        let mut rows = 0;
        for &node_id in &chain {
            let blocks = self.node(node_id).layers.clone();
            let Some(adopted) = adopt_chunk(pool, &blocks) else {
                // Unreachable while the index holds its references —
                // defensive: the already-adopted chain is still a valid
                // (shorter) prefix, so return it.
                return PrefixMatch { rows, layers };
            };
            commit_chunk(&mut layers, adopted);
            self.node_mut(node_id).last_use = clock;
            rows += self.block_tokens;
        }
        if let Some((ti, tail_rows)) = tail {
            let blocks = self.node(last).tails[ti].layers.clone();
            if let Some(adopted) = adopt_chunk(pool, &blocks) {
                commit_chunk(&mut layers, adopted);
                self.node_mut(last).tails[ti].last_use = clock;
                rows += tail_rows;
            }
        }
        PrefixMatch { rows, layers }
    }

    /// Publish a just-prefilled prompt: `per_layer` holds, per layer,
    /// the (K ids, V ids) block chains covering the prompt (from
    /// `LayerKvCache::freeze_prefix`). Chunks already in the trie are
    /// left as-is (first publisher wins); new chunks and a new partial
    /// tail take index-held references on their blocks.
    pub fn insert(
        &mut self,
        prompt: &[u16],
        per_layer: &[(Vec<BlockId>, Vec<BlockId>)],
        pool: &BlockPool,
    ) {
        assert_eq!(per_layer.len(), self.n_layers);
        self.clock += 1;
        let clock = self.clock;
        let bs = self.block_tokens;
        let full = prompt.len() / bs;
        let n_pages = prompt.len().div_ceil(bs);
        for (ks, vs) in per_layer {
            assert_eq!(ks.len(), n_pages, "freeze must cover the whole prompt");
            assert_eq!(vs.len(), n_pages, "freeze must cover the whole prompt");
        }
        let mut node = 0usize;
        for (i, chunk) in prompt.chunks_exact(bs).enumerate() {
            let existing = self.node(node).children.get(chunk).copied();
            if let Some(child) = existing {
                node = child;
                self.node_mut(node).last_use = clock;
                continue;
            }
            let blocks: Vec<(BlockId, BlockId)> =
                per_layer.iter().map(|(ks, vs)| (ks[i], vs[i])).collect();
            for &(k, v) in &blocks {
                pool.retain(k);
                pool.retain(v);
            }
            let child = self.new_node(Node {
                parent: node,
                key: chunk.to_vec(),
                children: HashMap::new(),
                layers: blocks,
                tails: Vec::new(),
                last_use: clock,
            });
            self.node_mut(node).children.insert(chunk.to_vec(), child);
            node = child;
        }
        let remaining = &prompt[full * bs..];
        if remaining.is_empty() || self.node(node).tails.iter().any(|t| t.tokens == remaining) {
            return;
        }
        let blocks: Vec<(BlockId, BlockId)> =
            per_layer.iter().map(|(ks, vs)| (ks[full], vs[full])).collect();
        for &(k, v) in &blocks {
            pool.retain(k);
            pool.retain(v);
        }
        self.node_mut(node).tails.push(Tail {
            tokens: remaining.to_vec(),
            layers: blocks,
            last_use: clock,
        });
    }

    fn new_node(&mut self, node: Node) -> usize {
        if let Some(id) = self.free_nodes.pop() {
            self.nodes[id] = Some(node);
            id
        } else {
            self.nodes.push(Some(node));
            self.nodes.len() - 1
        }
    }

    /// Release index references least-recently-used first until the pool
    /// has `need` uncommitted blocks free (or nothing evictable is
    /// left). Tails and childless leaf nodes are the candidates; evicting
    /// a leaf can expose its parent on the next round. Blocks still
    /// referenced by live sessions lose only their index entry — their
    /// memory returns to the pool when those sessions retire.
    pub fn evict_lru(&mut self, pool: &BlockPool, need: usize) {
        while pool.free_uncommitted() < need {
            // LRU candidate: any tail, or any childless+tailless node.
            // Linear scan per eviction — O(nodes) each — chosen for
            // simplicity; tries here hold distinct *published prompts*
            // (not tokens), small at current scale. Revisit with an
            // intrusive LRU list if eviction ever shows up in profiles.
            let mut best_lu = u64::MAX;
            let mut best: Option<(usize, Option<usize>)> = None; // (node, tail idx)
            for (id, slot) in self.nodes.iter().enumerate() {
                let Some(node) = slot else { continue };
                for (ti, tail) in node.tails.iter().enumerate() {
                    if tail.last_use < best_lu {
                        best_lu = tail.last_use;
                        best = Some((id, Some(ti)));
                    }
                }
                let leaf = id != 0 && node.children.is_empty() && node.tails.is_empty();
                if leaf && node.last_use < best_lu {
                    best_lu = node.last_use;
                    best = Some((id, None));
                }
            }
            let Some((id, tail)) = best else { return };
            match tail {
                Some(ti) => {
                    let t = self.node_mut(id).tails.swap_remove(ti);
                    for (k, v) in t.layers {
                        pool.release(k);
                        pool.release(v);
                    }
                }
                None => {
                    let node = self.nodes[id].take().expect("live node");
                    self.node_mut(node.parent).children.remove(&node.key);
                    for (k, v) in node.layers {
                        pool.release(k);
                        pool.release(v);
                    }
                    self.free_nodes.push(id);
                }
            }
        }
    }

    /// Drop every index entry, releasing all index-held references —
    /// used on shutdown and by leak tests ("no blocks in use once the
    /// index is cleared and every session has retired").
    pub fn clear(&mut self, pool: &BlockPool) {
        for slot in self.nodes.iter_mut().skip(1) {
            let Some(node) = slot.take() else { continue };
            release_node(pool, node);
        }
        let root = self.node_mut(0);
        root.children.clear();
        let tails = std::mem::take(&mut root.tails);
        for t in tails {
            for (k, v) in t.layers {
                pool.release(k);
                pool.release(v);
            }
        }
        self.free_nodes = (1..self.nodes.len()).collect();
    }
}

/// Adopt one chunk's per-layer (K, V) blocks all-or-nothing.
fn adopt_chunk(
    pool: &BlockPool,
    blocks: &[(BlockId, BlockId)],
) -> Option<Vec<(AdoptedBlock, AdoptedBlock)>> {
    let mut got = Vec::with_capacity(blocks.len());
    for &(k, v) in blocks {
        let kd = pool.adopt(k);
        let vd = pool.adopt(v);
        match (kd, vd) {
            (Some(kd), Some(vd)) => got.push((
                AdoptedBlock { id: k, data: kd },
                AdoptedBlock { id: v, data: vd },
            )),
            (kd, vd) => {
                if kd.is_some() {
                    pool.release(k);
                }
                if vd.is_some() {
                    pool.release(v);
                }
                for (a, b) in got {
                    pool.release(a.id);
                    pool.release(b.id);
                }
                return None;
            }
        }
    }
    Some(got)
}

fn commit_chunk(
    layers: &mut [(Vec<AdoptedBlock>, Vec<AdoptedBlock>)],
    adopted: Vec<(AdoptedBlock, AdoptedBlock)>,
) {
    for (l, (k, v)) in adopted.into_iter().enumerate() {
        layers[l].0.push(k);
        layers[l].1.push(v);
    }
}

fn release_node(pool: &BlockPool, node: Node) {
    for (k, v) in node.layers {
        pool.release(k);
        pool.release(v);
    }
    for t in node.tails {
        for (k, v) in t.layers {
            pool.release(k);
            pool.release(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::{KvPoolConfig, PagedKv4Store};
    use crate::util::rng::Rng;

    fn pool(blocks: usize, bs: usize) -> Arc<BlockPool> {
        Arc::new(BlockPool::new(KvPoolConfig {
            blocks,
            block_tokens: bs,
        }))
    }

    /// Publish one single-layer "prompt": a K store and a V store
    /// holding `prompt.len()` rows, frozen and inserted.
    fn publish(
        index: &mut PrefixIndex,
        pool: &Arc<BlockPool>,
        prompt: &[u16],
        d: usize,
        seed: u64,
    ) -> (PagedKv4Store, PagedKv4Store) {
        let mut rng = Rng::new(seed);
        let mut k = PagedKv4Store::new(d, pool.clone());
        let mut v = PagedKv4Store::new(d, pool.clone());
        for _ in prompt {
            k.push(&rng.normal_vec_f32(d, 0.0, 1.0));
            v.push(&rng.normal_vec_f32(d, 0.0, 1.0));
        }
        let ks = k.freeze_prefix(prompt.len());
        let vs = v.freeze_prefix(prompt.len());
        index.insert(prompt, &[(ks, vs)], pool);
        (k, v)
    }

    #[test]
    fn match_is_block_aligned_and_capped_below_the_full_prompt() {
        let p = pool(64, 4);
        let mut idx = PrefixIndex::new(4, 1);
        let prompt: Vec<u16> = (0..10).collect(); // 2 full blocks + tail [8, 9]
        let _stores = publish(&mut idx, &p, &prompt, 8, 1);

        // same first block, divergent second block: block-aligned match
        let q: Vec<u16> = vec![0, 1, 2, 3, 99, 98, 97, 96, 5];
        assert_eq!(idx.match_rows(&q), 4);

        // identical prompt: 2 full blocks + 1 tail row (capped at len-1)
        assert_eq!(idx.match_rows(&prompt), 9);

        // prompt extending the published one: full blocks + whole tail
        let longer: Vec<u16> = (0..16).collect();
        assert_eq!(idx.match_rows(&longer), 10);

        // diverging inside the first block: no block-aligned match
        let r: Vec<u16> = vec![0, 1, 7, 3, 4, 5];
        assert_eq!(idx.match_rows(&r), 0);

        // exactly one published block as the whole prompt: the cap
        // leaves the final token for the suffix prefill, so the full
        // block cannot be matched — only nothing or a shorter tail.
        let one: Vec<u16> = (0..4).collect();
        assert_eq!(idx.match_rows(&one), 0);
    }

    #[test]
    fn lookup_adopts_and_release_balances() {
        let p = pool(64, 4);
        let mut idx = PrefixIndex::new(4, 1);
        let prompt: Vec<u16> = (0..10).collect();
        let stores = publish(&mut idx, &p, &prompt, 8, 2);
        let baseline = p.in_use();

        let m = idx.lookup(&(0..16).collect::<Vec<u16>>(), &p);
        assert_eq!(m.rows, 10, "2 full blocks + the whole 2-row tail");
        assert_eq!(m.layers.len(), 1);
        assert_eq!(m.layers[0].0.len(), 3, "2 full K blocks + shared tail");
        assert_eq!(m.full_blocks(4), 2);
        // adoption bumped refcounts but allocated nothing new
        assert_eq!(p.in_use(), baseline);
        m.release(&p);
        assert_eq!(p.in_use(), baseline);
        drop(stores);
        // the index still pins the published blocks after the stores die
        assert_eq!(p.in_use(), 6, "2 full + 1 tail, for K and for V");
    }

    #[test]
    fn eviction_frees_lru_entries_until_capacity_is_available() {
        let bs = 4;
        let p = pool(6, bs);
        let mut idx = PrefixIndex::new(bs, 1);
        // two published single-block prompts: 2 blocks each (K + V)
        let a: Vec<u16> = (0..4).collect();
        let b: Vec<u16> = (100..104).collect();
        let sa = publish(&mut idx, &p, &a, 8, 3);
        let sb = publish(&mut idx, &p, &b, 8, 4);
        drop((sa, sb));
        assert_eq!(p.in_use(), 4, "index pins both chains");

        // touch `a` (via a longer probe — matching is capped below the
        // full prompt) so `b` becomes the LRU chain
        let probe_a: Vec<u16> = (0..6).collect();
        let m = idx.lookup(&probe_a, &p);
        assert_eq!(m.rows, 4);
        m.release(&p);

        idx.evict_lru(&p, 4);
        assert!(p.free_uncommitted() >= 4);
        assert_eq!(idx.match_rows(&probe_a), 4, "recently used chain survives");
        assert_eq!(idx.match_rows(&(100..106).collect::<Vec<u16>>()), 0, "LRU chain evicted");

        idx.clear(&p);
        assert_eq!(p.in_use(), 0, "clear releases every index reference");
    }

    /// A prompt shorter than one block publishes a root tail that a
    /// longer identical-prefix prompt can adopt (and CoW past).
    #[test]
    fn sub_block_prompt_is_shared_via_a_root_tail() {
        let p = pool(16, 8);
        let mut idx = PrefixIndex::new(8, 1);
        let prompt: Vec<u16> = vec![5, 6, 7];
        let _stores = publish(&mut idx, &p, &prompt, 8, 5);
        assert_eq!(idx.match_rows(&[5, 6, 7, 8, 9]), 3);
        assert_eq!(idx.match_rows(&[5, 6, 7]), 2, "capped at len - 1");
        assert_eq!(idx.match_rows(&[5, 9, 7, 8]), 1, "tail matches token-wise");
    }
}
