//! Word-level tokenizer for the synthetic micro-language: bidirectional
//! token-id ↔ word-string mapping used by the serving demo and the CLI
//! (the corpora themselves are generated directly as token ids).

use super::corpus::*;
use std::collections::HashMap;

pub struct Tokenizer {
    words: Vec<String>,
    lookup: HashMap<String, u16>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Tokenizer {
        let mut words = vec![String::new(); VOCAB_SIZE];
        words[PAD as usize] = "<pad>".into();
        words[BOS as usize] = "<s>".into();
        words[EOS as usize] = "</s>".into();
        words[SEP as usize] = ".".into();
        words[QRY as usize] = "?".into();
        words[YES as usize] = "yes".into();
        words[NO as usize] = "no".into();
        words[7] = "<unk>".into();
        for i in 0..N_ENT {
            words[(ENT_BASE + i) as usize] = format!("ent{i}");
        }
        for i in 0..N_REL {
            words[(REL_BASE + i) as usize] = format!("rel{i}");
        }
        for i in 0..N_OBJ {
            words[(OBJ_BASE + i) as usize] = format!("obj{i}");
        }
        for i in 0..N_FILL {
            words[(FILL_BASE + i) as usize] = format!("w{i}");
        }
        let lookup = words
            .iter()
            .enumerate()
            .filter(|(_, w)| !w.is_empty())
            .map(|(i, w)| (w.clone(), i as u16))
            .collect();
        Tokenizer { words, lookup }
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB_SIZE
    }

    pub fn decode_one(&self, id: u16) -> &str {
        self.words
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<unk>")
    }

    pub fn decode(&self, ids: &[u16]) -> String {
        ids.iter()
            .map(|&i| self.decode_one(i))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn encode_one(&self, word: &str) -> u16 {
        self.lookup.get(word).copied().unwrap_or(7) // <unk>
    }

    pub fn encode(&self, text: &str) -> Vec<u16> {
        text.split_whitespace().map(|w| self.encode_one(w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_every_token() {
        let tok = Tokenizer::new();
        for id in 0..VOCAB_SIZE as u16 {
            let w = tok.decode_one(id).to_string();
            if w != "<unk>" || id == 7 {
                assert_eq!(tok.encode_one(&w), id, "token {id} ({w})");
            }
        }
    }

    #[test]
    fn encode_decode_sentence() {
        let tok = Tokenizer::new();
        let text = "? ent3 rel7 obj14 .";
        let ids = tok.encode(text);
        assert_eq!(ids[0], QRY);
        assert_eq!(tok.decode(&ids), text);
    }

    #[test]
    fn unknown_maps_to_unk() {
        let tok = Tokenizer::new();
        assert_eq!(tok.encode_one("zzz-not-a-word"), 7);
    }
}
