//! Synthetic data substrate: corpora (wiki/ptb/c4 analogs), tokenizer,
//! calibration sampling, and the on-disk token format shared with the JAX
//! trainer.

pub mod corpus;
pub mod tokenizer;

use crate::util::rng::Rng;
use std::io::{Read, Write};
use std::path::Path;

/// Sample `n_seqs` calibration windows of `seq_len` tokens from a stream
/// (paper: 128 random samples of len 2048 from the WikiText2 train set;
/// tiny scale: 32 × 128 by default, set in configs/).
pub fn calibration_windows(
    stream: &[u16],
    n_seqs: usize,
    seq_len: usize,
    seed: u64,
) -> Vec<Vec<u16>> {
    assert!(stream.len() > seq_len, "stream too short");
    let mut rng = Rng::new(seed);
    (0..n_seqs)
        .map(|_| {
            let start = rng.below(stream.len() - seq_len);
            stream[start..start + seq_len].to_vec()
        })
        .collect()
}

/// Write a token stream: magic "BWATOK1\0", u64 count, u16 LE tokens.
pub fn save_tokens(path: &Path, tokens: &[u16]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(b"BWATOK1\0")?;
    f.write_all(&(tokens.len() as u64).to_le_bytes())?;
    let bytes: Vec<u8> = tokens.iter().flat_map(|t| t.to_le_bytes()).collect();
    f.write_all(&bytes)?;
    Ok(())
}

pub fn load_tokens(path: &Path) -> std::io::Result<Vec<u16>> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != b"BWATOK1\0" {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad token-file magic",
        ));
    }
    let mut cnt8 = [0u8; 8];
    f.read_exact(&mut cnt8)?;
    let n = u64::from_le_bytes(cnt8) as usize;
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;
    if payload.len() != 2 * n {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "token payload length mismatch",
        ));
    }
    Ok(payload
        .chunks_exact(2)
        .map(|b| u16::from_le_bytes([b[0], b[1]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_windows_shape_and_bounds() {
        let stream: Vec<u16> = (0..10_000).map(|i| (i % 500) as u16).collect();
        let wins = calibration_windows(&stream, 8, 128, 42);
        assert_eq!(wins.len(), 8);
        for w in &wins {
            assert_eq!(w.len(), 128);
        }
        // deterministic
        let wins2 = calibration_windows(&stream, 8, 128, 42);
        assert_eq!(wins, wins2);
    }

    #[test]
    fn token_file_roundtrip() {
        let dir = std::env::temp_dir().join("bwa_tok_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.tok");
        let toks: Vec<u16> = (0..1000).map(|i| (i * 7 % 512) as u16).collect();
        save_tokens(&path, &toks).unwrap();
        let back = load_tokens(&path).unwrap();
        assert_eq!(toks, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn token_file_rejects_garbage() {
        let dir = std::env::temp_dir().join("bwa_tok_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.tok");
        std::fs::write(&path, b"garbage").unwrap();
        assert!(load_tokens(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
