//! Deterministic synthetic corpora standing in for WikiText2 / PTB / C4
//! (DESIGN.md §2): a relational micro-language over a 512-token vocab.
//!
//! The world is a fixed fact table `obj = fact(entity, relation)`; corpora
//! are streams of sentences mixing fact triples, query-formatted facts
//! (which later power the zero-shot tasks), boolean verification
//! sentences, and filler noise. The three flavors differ in noise rate,
//! corruption rate, and entity distribution, giving the FP model the same
//! PPL ordering the paper reports (wiki < c4 ≪ ptb).
//!
//! Everything is seeded and pure — the JAX trainer consumes the exact
//! token streams via `artifacts/data/*.tok` written by `bwa datagen`.

use crate::util::rng::Rng;

pub const VOCAB_SIZE: usize = 512;

// token layout
pub const PAD: u16 = 0;
pub const BOS: u16 = 1;
pub const EOS: u16 = 2;
pub const SEP: u16 = 3;
pub const QRY: u16 = 4;
pub const YES: u16 = 5;
pub const NO: u16 = 6;
pub const ENT_BASE: u16 = 8;
pub const N_ENT: u16 = 80;
pub const REL_BASE: u16 = ENT_BASE + N_ENT; // 88
pub const N_REL: u16 = 40;
pub const OBJ_BASE: u16 = REL_BASE + N_REL; // 128
pub const N_OBJ: u16 = 120;
pub const FILL_BASE: u16 = OBJ_BASE + N_OBJ; // 248
pub const N_FILL: u16 = VOCAB_SIZE as u16 - FILL_BASE; // 264

/// The ground-truth fact table: object index for (entity, relation).
#[inline]
pub fn fact_obj(e: u16, r: u16) -> u16 {
    debug_assert!(e < N_ENT && r < N_REL);
    OBJ_BASE + ((e as u32 * 37 + r as u32 * 101 + 13) % N_OBJ as u32) as u16
}

/// MMLU-analog domain of a relation (4 domains à 10 relations).
pub fn relation_domain(r: u16) -> usize {
    (r as usize) / 10
}

pub const DOMAIN_NAMES: [&str; 4] = ["STEM", "humanities", "social science", "others"];

/// Corpus flavor parameters.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub name: &'static str,
    pub seed: u64,
    /// probability of a filler (noise) sentence
    pub noise: f64,
    /// probability that a fact sentence carries a corrupted object
    pub corrupt: f64,
    /// Zipf-like skew for entity sampling (higher = more concentrated)
    pub skew: f64,
    /// probability of query-formatted sentences (teaches the QA format)
    pub query_frac: f64,
    /// probability of boolean verification sentences
    pub bool_frac: f64,
}

impl CorpusSpec {
    pub fn wiki() -> Self {
        Self {
            name: "wiki",
            seed: 101,
            noise: 0.10,
            corrupt: 0.02,
            skew: 1.1,
            query_frac: 0.15,
            bool_frac: 0.08,
        }
    }

    pub fn ptb() -> Self {
        Self {
            name: "ptb",
            seed: 202,
            noise: 0.55,
            corrupt: 0.25,
            skew: 0.6,
            query_frac: 0.05,
            bool_frac: 0.03,
        }
    }

    pub fn c4() -> Self {
        Self {
            name: "c4",
            seed: 303,
            noise: 0.30,
            corrupt: 0.08,
            skew: 0.9,
            query_frac: 0.10,
            bool_frac: 0.05,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "wiki" => Some(Self::wiki()),
            "ptb" => Some(Self::ptb()),
            "c4" => Some(Self::c4()),
            _ => None,
        }
    }
}

/// Zipf-ish sampler over [0, n) with skew s (s = 0 → uniform).
fn zipf(rng: &mut Rng, n: u16, s: f64) -> u16 {
    if s <= 0.0 {
        return rng.below(n as usize) as u16;
    }
    // inverse-CDF approximation: u^(1/(1-s')) concentration
    let u = rng.f64();
    let x = u.powf(1.0 + s);
    ((x * n as f64) as usize).min(n as usize - 1) as u16
}

/// One sentence appended to `out` (always SEP-terminated).
fn emit_sentence(rng: &mut Rng, spec: &CorpusSpec, out: &mut Vec<u16>) {
    let roll = rng.f64();
    if roll < spec.noise {
        // filler noise: 3..8 filler tokens
        let len = 3 + rng.below(6);
        for _ in 0..len {
            out.push(FILL_BASE + zipf(rng, N_FILL, 0.8));
        }
        out.push(SEP);
        return;
    }
    let e = zipf(rng, N_ENT, spec.skew);
    let r = rng.below(N_REL as usize) as u16;
    let true_obj = fact_obj(e, r);
    let obj = if rng.bool(spec.corrupt) {
        OBJ_BASE + rng.below(N_OBJ as usize) as u16
    } else {
        true_obj
    };
    let roll2 = rng.f64();
    if roll2 < spec.bool_frac {
        // boolean verification: QRY e r o YES/NO
        let claim_true = rng.bool(0.5);
        let claimed = if claim_true {
            true_obj
        } else {
            // a wrong object, never the true one
            let mut o = OBJ_BASE + rng.below(N_OBJ as usize) as u16;
            while o == true_obj {
                o = OBJ_BASE + rng.below(N_OBJ as usize) as u16;
            }
            o
        };
        out.extend_from_slice(&[QRY, ENT_BASE + e, REL_BASE + r, claimed]);
        out.push(if claim_true { YES } else { NO });
        out.push(SEP);
    } else if roll2 < spec.bool_frac + spec.query_frac {
        // query format: QRY e r o
        out.extend_from_slice(&[QRY, ENT_BASE + e, REL_BASE + r, obj, SEP]);
    } else {
        // plain fact: e r o
        out.extend_from_slice(&[ENT_BASE + e, REL_BASE + r, obj, SEP]);
    }
}

/// Generate a token stream of (at least) `n_tokens` tokens.
pub fn generate(spec: &CorpusSpec, n_tokens: usize) -> Vec<u16> {
    let mut rng = Rng::new(spec.seed);
    let mut out = Vec::with_capacity(n_tokens + 16);
    out.push(BOS);
    while out.len() < n_tokens {
        emit_sentence(&mut rng, spec, &mut out);
    }
    out.truncate(n_tokens);
    out
}

/// Train/eval split streams: eval uses a different stream (disjoint seed
/// offset) of the same flavor.
pub fn train_split(spec: &CorpusSpec, n_tokens: usize) -> Vec<u16> {
    generate(spec, n_tokens)
}

pub fn eval_split(spec: &CorpusSpec, n_tokens: usize) -> Vec<u16> {
    let mut s = spec.clone();
    s.seed ^= 0xE7A1_5EED;
    generate(&s, n_tokens)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = generate(&CorpusSpec::wiki(), 1000);
        let b = generate(&CorpusSpec::wiki(), 1000);
        assert_eq!(a, b);
        assert_eq!(a.len(), 1000);
    }

    #[test]
    fn flavors_differ() {
        let w = generate(&CorpusSpec::wiki(), 1000);
        let p = generate(&CorpusSpec::ptb(), 1000);
        assert_ne!(w, p);
    }

    #[test]
    fn tokens_in_vocab() {
        for spec in [CorpusSpec::wiki(), CorpusSpec::ptb(), CorpusSpec::c4()] {
            let toks = generate(&spec, 5000);
            for &t in &toks {
                assert!((t as usize) < VOCAB_SIZE, "token {t} out of vocab");
            }
        }
    }

    #[test]
    fn fact_table_is_deterministic_and_in_range() {
        for e in 0..N_ENT {
            for r in 0..N_REL {
                let o = fact_obj(e, r);
                assert!(o >= OBJ_BASE && o < OBJ_BASE + N_OBJ);
                assert_eq!(o, fact_obj(e, r));
            }
        }
    }

    #[test]
    fn wiki_mostly_facts_ptb_mostly_noise() {
        let count_fill = |toks: &[u16]| {
            toks.iter()
                .filter(|&&t| t >= FILL_BASE)
                .count() as f64
                / toks.len() as f64
        };
        let w = count_fill(&generate(&CorpusSpec::wiki(), 20_000));
        let p = count_fill(&generate(&CorpusSpec::ptb(), 20_000));
        assert!(w < 0.25, "wiki filler fraction {w}");
        assert!(p > 2.0 * w, "ptb ({p}) should be much noisier than wiki ({w})");
    }

    #[test]
    fn eval_split_differs_from_train() {
        let spec = CorpusSpec::wiki();
        let train = train_split(&spec, 2000);
        let eval = eval_split(&spec, 2000);
        assert_ne!(train, eval);
    }

    #[test]
    fn facts_consistent_in_uncorrupted_sentences() {
        // In the wiki corpus, the vast majority of (e, r, o) triples agree
        // with the fact table — the learnable signal.
        let toks = generate(&CorpusSpec::wiki(), 50_000);
        let mut total = 0;
        let mut correct = 0;
        let mut i = 0;
        while i + 2 < toks.len() {
            let (a, b, c) = (toks[i], toks[i + 1], toks[i + 2]);
            if (ENT_BASE..REL_BASE).contains(&a)
                && (REL_BASE..OBJ_BASE).contains(&b)
                && (OBJ_BASE..FILL_BASE).contains(&c)
            {
                total += 1;
                if c == fact_obj(a - ENT_BASE, b - REL_BASE) {
                    correct += 1;
                }
            }
            i += 1;
        }
        assert!(total > 1000, "not enough triples ({total})");
        let frac = correct as f64 / total as f64;
        assert!(frac > 0.9, "fact consistency {frac}");
    }
}
