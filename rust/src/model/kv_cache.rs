//! INT4 KV cache (paper §4 Setup: "For KV caches, we uniformly apply 4
//! bits quantization to store and load").
//!
//! Each appended token vector is RTN-quantized per token (asymmetric,
//! Eq. 3) and stored as packed nibbles + per-token params. `get` and
//! `dot`/`axpy` operate on the quantized representation, so the cache
//! really holds 4-bit state — the batch (non-cached) forward applies the
//! identical fake quantization, and tests assert the two paths agree.
//!
//! Two backings implement that representation behind one [`KvStore`]
//! facade: the private contiguous [`Kv4Store`] (one `Vec` per request)
//! and the pool-backed [`PagedKv4Store`](crate::kvpool::PagedKv4Store)
//! (fixed-size ref-counted blocks, shared-prefix reuse — see
//! [`crate::kvpool`]). Because quantization is per token, a row's bits
//! are identical wherever it lives, and the two backings are pinned
//! bit-identical on every serving path.

use crate::kvpool::{AdoptedBlock, BlockId, BlockPool, PagedKv4Store};
use crate::quant::rtn::RtnParams;
use std::sync::Arc;

/// Append-only 4-bit vector store of `d`-dimensional rows.
#[derive(Clone, Debug)]
pub struct Kv4Store {
    pub d: usize,
    pub len: usize,
    /// packed nibbles, two per byte, row-major.
    data: Vec<u8>,
    params: Vec<RtnParams>,
}

impl Kv4Store {
    pub fn new(d: usize) -> Self {
        Self::with_capacity(d, 0)
    }

    /// Contiguous store with room for `rows` vectors reserved up front.
    /// This is the *private* backing: lockstep serving knows
    /// `prompt + gen` per request and reserves it here, so this `Vec`
    /// never reallocates mid-request — at the cost of every request
    /// paying its worst case. The paged backing
    /// ([`crate::kvpool::PagedKv4Store`]) instead allocates fixed-size
    /// blocks from a shared [`crate::kvpool::BlockPool`] on demand and
    /// can share a prompt prefix between requests; both sit behind
    /// [`KvStore`] and hold bit-identical rows.
    pub fn with_capacity(d: usize, rows: usize) -> Self {
        assert!(d % 2 == 0, "d must be even for nibble packing");
        Self {
            d,
            len: 0,
            data: Vec::with_capacity(rows * d / 2),
            params: Vec::with_capacity(rows),
        }
    }

    /// Quantize and append one row.
    pub fn push(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.d);
        let p = RtnParams::fit(row, 4);
        for pair in row.chunks_exact(2) {
            let lo = p.quantize_one(pair[0]) as u8;
            let hi = p.quantize_one(pair[1]) as u8;
            self.data.push(lo | (hi << 4));
        }
        self.params.push(p);
        self.len += 1;
    }

    /// Dequantize row `t` into `out`.
    pub fn get(&self, t: usize, out: &mut [f32]) {
        assert!(t < self.len);
        assert_eq!(out.len(), self.d);
        let p = &self.params[t];
        let bytes = &self.data[t * self.d / 2..(t + 1) * self.d / 2];
        for (i, &b) in bytes.iter().enumerate() {
            out[2 * i] = p.dequantize_one((b & 0x0F) as i32);
            out[2 * i + 1] = p.dequantize_one((b >> 4) as i32);
        }
    }

    /// Dot product of row `t` with a query slice (dequantize on the fly).
    pub fn dot(&self, t: usize, q: &[f32]) -> f32 {
        debug_assert_eq!(q.len(), self.d);
        let p = &self.params[t];
        let bytes = &self.data[t * self.d / 2..(t + 1) * self.d / 2];
        let mut acc_q = 0.0f32; // Σ q_i · code_i
        let mut acc_s = 0.0f32; // Σ q_i  (for the zero-point term)
        for (i, &b) in bytes.iter().enumerate() {
            let c0 = (b & 0x0F) as f32;
            let c1 = (b >> 4) as f32;
            acc_q += q[2 * i] * c0 + q[2 * i + 1] * c1;
            acc_s += q[2 * i] + q[2 * i + 1];
        }
        p.scale * (acc_q - p.zero as f32 * acc_s)
    }

    /// out += w · row_t (dequantized) — the attention value accumulation.
    pub fn axpy(&self, t: usize, w: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d);
        let p = &self.params[t];
        let bytes = &self.data[t * self.d / 2..(t + 1) * self.d / 2];
        for (i, &b) in bytes.iter().enumerate() {
            out[2 * i] += w * p.dequantize_one((b & 0x0F) as i32);
            out[2 * i + 1] += w * p.dequantize_one((b >> 4) as i32);
        }
    }

    /// Drop every row past `rows` — speculative-decode rollback of
    /// rejected draft positions.
    pub fn truncate(&mut self, rows: usize) {
        assert!(rows <= self.len, "truncating rows the store does not hold");
        self.data.truncate(rows * self.d / 2);
        self.params.truncate(rows);
        self.len = rows;
    }

    /// Apply the cache's quantization to a row without storing it — the
    /// batch forward uses this so both paths share one code path.
    pub fn fake_quantize(row: &mut [f32]) {
        let p = RtnParams::fit(row, 4);
        for x in row.iter_mut() {
            *x = p.dequantize_one(p.quantize_one(*x));
        }
    }

    pub fn bytes(&self) -> usize {
        self.data.len() + self.params.len() * 8
    }
}

/// One INT4 row store behind either backing. Every method forwards to
/// the same per-row math, so the choice of backing never changes a
/// value — only where the bits live and whether they can be shared.
#[derive(Debug)]
pub enum KvStore {
    /// Private contiguous `Vec` (lockstep serving, one per request).
    Contiguous(Kv4Store),
    /// Pool-backed paged store (continuous serving, prefix sharing).
    Paged(PagedKv4Store),
}

impl KvStore {
    pub fn len(&self) -> usize {
        match self {
            KvStore::Contiguous(s) => s.len,
            KvStore::Paged(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Quantize and append one row.
    pub fn push(&mut self, row: &[f32]) {
        match self {
            KvStore::Contiguous(s) => s.push(row),
            KvStore::Paged(s) => s.push(row),
        }
    }

    /// Dequantize row `t` into `out`.
    pub fn get(&self, t: usize, out: &mut [f32]) {
        match self {
            KvStore::Contiguous(s) => s.get(t, out),
            KvStore::Paged(s) => s.get(t, out),
        }
    }

    /// Dot product of row `t` with a query slice.
    pub fn dot(&self, t: usize, q: &[f32]) -> f32 {
        match self {
            KvStore::Contiguous(s) => s.dot(t, q),
            KvStore::Paged(s) => s.dot(t, q),
        }
    }

    /// out += w · row_t (dequantized).
    pub fn axpy(&self, t: usize, w: f32, out: &mut [f32]) {
        match self {
            KvStore::Contiguous(s) => s.axpy(t, w, out),
            KvStore::Paged(s) => s.axpy(t, w, out),
        }
    }

    /// Drop every row past `rows` — speculative-decode rollback. Both
    /// backings land in the identical post-rollback state as a store
    /// that never held the rejected rows (the paged backing also returns
    /// whole rejected tail blocks to its pool).
    pub fn truncate(&mut self, rows: usize) {
        match self {
            KvStore::Contiguous(s) => s.truncate(rows),
            KvStore::Paged(s) => s.truncate(rows),
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            KvStore::Contiguous(s) => s.bytes(),
            KvStore::Paged(s) => s.bytes(),
        }
    }

    /// The paged backing, if that is what this store is — publishing a
    /// prefix to the [`crate::kvpool::PrefixIndex`] needs it.
    pub fn as_paged_mut(&mut self) -> Option<&mut PagedKv4Store> {
        match self {
            KvStore::Contiguous(_) => None,
            KvStore::Paged(s) => Some(s),
        }
    }

    /// Pool blocks this store allocated, net of rollback releases
    /// ([`PagedKv4Store::blocks_drawn`]); a contiguous store draws
    /// nothing from any pool. Retirement/preemption refunds the session's
    /// unconsumed reservation with this.
    pub fn blocks_drawn(&self) -> usize {
        match self {
            KvStore::Contiguous(_) => 0,
            KvStore::Paged(s) => s.blocks_drawn(),
        }
    }
}

/// Per-layer K and V stores for one sequence.
#[derive(Debug)]
pub struct LayerKvCache {
    pub k: KvStore,
    pub v: KvStore,
}

impl LayerKvCache {
    pub fn new(d: usize) -> Self {
        Self {
            k: KvStore::Contiguous(Kv4Store::new(d)),
            v: KvStore::Contiguous(Kv4Store::new(d)),
        }
    }

    /// Contiguous K and V stores with `rows` positions reserved (see
    /// [`Kv4Store::with_capacity`]).
    pub fn with_capacity(d: usize, rows: usize) -> Self {
        Self {
            k: KvStore::Contiguous(Kv4Store::with_capacity(d, rows)),
            v: KvStore::Contiguous(Kv4Store::with_capacity(d, rows)),
        }
    }

    /// Empty paged K and V stores allocating blocks from `pool`.
    pub fn paged(d: usize, pool: &Arc<BlockPool>) -> Self {
        Self {
            k: KvStore::Paged(PagedKv4Store::new(d, pool.clone())),
            v: KvStore::Paged(PagedKv4Store::new(d, pool.clone())),
        }
    }

    /// Paged K and V stores seeded with `rows` rows of adopted prefix
    /// blocks (refcounts already held by the caller's
    /// [`crate::kvpool::PrefixMatch`]).
    pub fn paged_from_prefix(
        d: usize,
        pool: &Arc<BlockPool>,
        k_blocks: Vec<AdoptedBlock>,
        v_blocks: Vec<AdoptedBlock>,
        rows: usize,
    ) -> Self {
        let to_pages = |blocks: Vec<AdoptedBlock>| {
            blocks.into_iter().map(|b| (b.id, b.data)).collect::<Vec<_>>()
        };
        let k = PagedKv4Store::from_prefix(d, pool.clone(), to_pages(k_blocks), rows);
        let v = PagedKv4Store::from_prefix(d, pool.clone(), to_pages(v_blocks), rows);
        Self {
            k: KvStore::Paged(k),
            v: KvStore::Paged(v),
        }
    }

    /// Freeze the K and V blocks covering rows `[0, rows)` for sharing;
    /// `None` if this cache is contiguous (nothing shareable). Returns
    /// the (K ids, V ids) chains the prefix index records.
    pub fn freeze_prefix(&mut self, rows: usize) -> Option<(Vec<BlockId>, Vec<BlockId>)> {
        let ks = self.k.as_paged_mut()?.freeze_prefix(rows);
        let vs = self.v.as_paged_mut()?.freeze_prefix(rows);
        Some((ks, vs))
    }

    /// Roll both streams back to `rows` positions — speculative-decode
    /// rollback of rejected draft tokens.
    pub fn truncate(&mut self, rows: usize) {
        self.k.truncate(rows);
        self.v.truncate(rows);
    }

    pub fn len(&self) -> usize {
        self.k.len()
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }

    /// Pool blocks both streams allocated, net of rollbacks (see
    /// [`KvStore::blocks_drawn`]).
    pub fn blocks_drawn(&self) -> usize {
        self.k.blocks_drawn() + self.v.blocks_drawn()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn push_get_matches_fake_quantize() {
        let mut rng = Rng::new(1);
        let d = 64;
        let mut store = Kv4Store::new(d);
        let rows: Vec<Vec<f32>> = (0..10).map(|_| rng.normal_vec_f32(d, 0.0, 1.0)).collect();
        for r in &rows {
            store.push(r);
        }
        let mut out = vec![0.0f32; d];
        for (t, r) in rows.iter().enumerate() {
            store.get(t, &mut out);
            let mut fake = r.clone();
            Kv4Store::fake_quantize(&mut fake);
            prop::assert_close(&out, &fake, 1e-6, 0.0).unwrap();
        }
    }

    #[test]
    fn dot_matches_dequantized_dot() {
        let mut rng = Rng::new(2);
        let d = 64;
        let mut store = Kv4Store::new(d);
        let row = rng.normal_vec_f32(d, 0.2, 1.5);
        store.push(&row);
        let q = rng.normal_vec_f32(d, 0.0, 1.0);
        let mut dq = vec![0.0f32; d];
        store.get(0, &mut dq);
        let want: f32 = dq.iter().zip(&q).map(|(a, b)| a * b).sum();
        let got = store.dot(0, &q);
        assert!((got - want).abs() < 1e-3, "{got} vs {want}");
    }

    #[test]
    fn axpy_accumulates() {
        let mut rng = Rng::new(3);
        let d = 32;
        let mut store = Kv4Store::new(d);
        let r0 = rng.normal_vec_f32(d, 0.0, 1.0);
        let r1 = rng.normal_vec_f32(d, 0.0, 1.0);
        store.push(&r0);
        store.push(&r1);
        let mut out = vec![0.0f32; d];
        store.axpy(0, 0.25, &mut out);
        store.axpy(1, 0.75, &mut out);
        let mut d0 = vec![0.0f32; d];
        let mut d1 = vec![0.0f32; d];
        store.get(0, &mut d0);
        store.get(1, &mut d1);
        for i in 0..d {
            let want = 0.25 * d0[i] + 0.75 * d1[i];
            assert!((out[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn quantization_error_bounded() {
        let mut rng = Rng::new(4);
        let d = 64;
        let mut store = Kv4Store::new(d);
        let row = rng.normal_vec_f32(d, 0.0, 2.0);
        store.push(&row);
        let mut out = vec![0.0f32; d];
        store.get(0, &mut out);
        let err = prop::rel_err(&out, &row);
        assert!(err < 0.1, "int4 kv error {err}");
    }

    #[test]
    fn truncate_then_repush_matches_a_never_drafted_store() {
        let mut rng = Rng::new(5);
        let d = 32;
        let rows: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec_f32(d, 0.0, 1.0)).collect();
        let mut drafted = Kv4Store::new(d);
        let mut plain = Kv4Store::new(d);
        for r in &rows[..5] {
            drafted.push(r);
            plain.push(r);
        }
        for r in &rows[5..] {
            drafted.push(r); // speculative rows, all rejected below
        }
        drafted.truncate(5);
        assert_eq!(drafted.len, 5);
        assert_eq!(drafted.bytes(), plain.bytes(), "rollback frees the draft rows' bytes");
        drafted.push(&rows[6]);
        plain.push(&rows[6]);
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        for t in 0..6 {
            drafted.get(t, &mut a);
            plain.get(t, &mut b);
            assert_eq!(a, b, "row {t} after rollback + repush");
        }
    }

    #[test]
    fn bytes_grows_linearly() {
        let mut store = Kv4Store::new(64);
        let row = vec![1.0f32; 64];
        store.push(&row);
        let one = store.bytes();
        store.push(&row);
        assert_eq!(store.bytes(), 2 * one);
    }
}
