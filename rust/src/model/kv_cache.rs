//! INT4 KV cache (paper §4 Setup: "For KV caches, we uniformly apply 4
//! bits quantization to store and load").
//!
//! Each appended token vector is RTN-quantized per token (asymmetric,
//! Eq. 3) and stored as packed nibbles + per-token params. `get` and
//! `dot`/`axpy` operate on the quantized representation, so the cache
//! really holds 4-bit state — the batch (non-cached) forward applies the
//! identical fake quantization, and tests assert the two paths agree.

use crate::quant::rtn::RtnParams;

/// Append-only 4-bit vector store of `d`-dimensional rows.
#[derive(Clone, Debug)]
pub struct Kv4Store {
    pub d: usize,
    pub len: usize,
    /// packed nibbles, two per byte, row-major.
    data: Vec<u8>,
    params: Vec<RtnParams>,
}

impl Kv4Store {
    pub fn new(d: usize) -> Self {
        Self::with_capacity(d, 0)
    }

    /// Store with room for `rows` vectors reserved up front (serving
    /// knows `prompt + gen` per request, so the cache never reallocates
    /// mid-request).
    pub fn with_capacity(d: usize, rows: usize) -> Self {
        assert!(d % 2 == 0, "d must be even for nibble packing");
        Self {
            d,
            len: 0,
            data: Vec::with_capacity(rows * d / 2),
            params: Vec::with_capacity(rows),
        }
    }

    /// Quantize and append one row.
    pub fn push(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.d);
        let p = RtnParams::fit(row, 4);
        for pair in row.chunks_exact(2) {
            let lo = p.quantize_one(pair[0]) as u8;
            let hi = p.quantize_one(pair[1]) as u8;
            self.data.push(lo | (hi << 4));
        }
        self.params.push(p);
        self.len += 1;
    }

    /// Dequantize row `t` into `out`.
    pub fn get(&self, t: usize, out: &mut [f32]) {
        assert!(t < self.len);
        assert_eq!(out.len(), self.d);
        let p = &self.params[t];
        let bytes = &self.data[t * self.d / 2..(t + 1) * self.d / 2];
        for (i, &b) in bytes.iter().enumerate() {
            out[2 * i] = p.dequantize_one((b & 0x0F) as i32);
            out[2 * i + 1] = p.dequantize_one((b >> 4) as i32);
        }
    }

    /// Dot product of row `t` with a query slice (dequantize on the fly).
    pub fn dot(&self, t: usize, q: &[f32]) -> f32 {
        debug_assert_eq!(q.len(), self.d);
        let p = &self.params[t];
        let bytes = &self.data[t * self.d / 2..(t + 1) * self.d / 2];
        let mut acc_q = 0.0f32; // Σ q_i · code_i
        let mut acc_s = 0.0f32; // Σ q_i  (for the zero-point term)
        for (i, &b) in bytes.iter().enumerate() {
            let c0 = (b & 0x0F) as f32;
            let c1 = (b >> 4) as f32;
            acc_q += q[2 * i] * c0 + q[2 * i + 1] * c1;
            acc_s += q[2 * i] + q[2 * i + 1];
        }
        p.scale * (acc_q - p.zero as f32 * acc_s)
    }

    /// out += w · row_t (dequantized) — the attention value accumulation.
    pub fn axpy(&self, t: usize, w: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d);
        let p = &self.params[t];
        let bytes = &self.data[t * self.d / 2..(t + 1) * self.d / 2];
        for (i, &b) in bytes.iter().enumerate() {
            out[2 * i] += w * p.dequantize_one((b & 0x0F) as i32);
            out[2 * i + 1] += w * p.dequantize_one((b >> 4) as i32);
        }
    }

    /// Apply the cache's quantization to a row without storing it — the
    /// batch forward uses this so both paths share one code path.
    pub fn fake_quantize(row: &mut [f32]) {
        let p = RtnParams::fit(row, 4);
        for x in row.iter_mut() {
            *x = p.dequantize_one(p.quantize_one(*x));
        }
    }

    pub fn bytes(&self) -> usize {
        self.data.len() + self.params.len() * 8
    }
}

/// Per-layer K and V stores for one sequence.
#[derive(Clone, Debug)]
pub struct LayerKvCache {
    pub k: Kv4Store,
    pub v: Kv4Store,
}

impl LayerKvCache {
    pub fn new(d: usize) -> Self {
        Self {
            k: Kv4Store::new(d),
            v: Kv4Store::new(d),
        }
    }

    /// K and V stores with `rows` positions reserved (see
    /// [`Kv4Store::with_capacity`]).
    pub fn with_capacity(d: usize, rows: usize) -> Self {
        Self {
            k: Kv4Store::with_capacity(d, rows),
            v: Kv4Store::with_capacity(d, rows),
        }
    }

    pub fn len(&self) -> usize {
        self.k.len
    }

    pub fn is_empty(&self) -> bool {
        self.k.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn push_get_matches_fake_quantize() {
        let mut rng = Rng::new(1);
        let d = 64;
        let mut store = Kv4Store::new(d);
        let rows: Vec<Vec<f32>> = (0..10).map(|_| rng.normal_vec_f32(d, 0.0, 1.0)).collect();
        for r in &rows {
            store.push(r);
        }
        let mut out = vec![0.0f32; d];
        for (t, r) in rows.iter().enumerate() {
            store.get(t, &mut out);
            let mut fake = r.clone();
            Kv4Store::fake_quantize(&mut fake);
            prop::assert_close(&out, &fake, 1e-6, 0.0).unwrap();
        }
    }

    #[test]
    fn dot_matches_dequantized_dot() {
        let mut rng = Rng::new(2);
        let d = 64;
        let mut store = Kv4Store::new(d);
        let row = rng.normal_vec_f32(d, 0.2, 1.5);
        store.push(&row);
        let q = rng.normal_vec_f32(d, 0.0, 1.0);
        let mut dq = vec![0.0f32; d];
        store.get(0, &mut dq);
        let want: f32 = dq.iter().zip(&q).map(|(a, b)| a * b).sum();
        let got = store.dot(0, &q);
        assert!((got - want).abs() < 1e-3, "{got} vs {want}");
    }

    #[test]
    fn axpy_accumulates() {
        let mut rng = Rng::new(3);
        let d = 32;
        let mut store = Kv4Store::new(d);
        let r0 = rng.normal_vec_f32(d, 0.0, 1.0);
        let r1 = rng.normal_vec_f32(d, 0.0, 1.0);
        store.push(&r0);
        store.push(&r1);
        let mut out = vec![0.0f32; d];
        store.axpy(0, 0.25, &mut out);
        store.axpy(1, 0.75, &mut out);
        let mut d0 = vec![0.0f32; d];
        let mut d1 = vec![0.0f32; d];
        store.get(0, &mut d0);
        store.get(1, &mut d1);
        for i in 0..d {
            let want = 0.25 * d0[i] + 0.75 * d1[i];
            assert!((out[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn quantization_error_bounded() {
        let mut rng = Rng::new(4);
        let d = 64;
        let mut store = Kv4Store::new(d);
        let row = rng.normal_vec_f32(d, 0.0, 2.0);
        store.push(&row);
        let mut out = vec![0.0f32; d];
        store.get(0, &mut out);
        let err = prop::rel_err(&out, &row);
        assert!(err < 0.1, "int4 kv error {err}");
    }

    #[test]
    fn bytes_grows_linearly() {
        let mut store = Kv4Store::new(64);
        let row = vec![1.0f32; 64];
        store.push(&row);
        let one = store.bytes();
        store.push(&row);
        assert_eq!(store.bytes(), 2 * one);
    }
}
