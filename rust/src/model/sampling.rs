//! Per-request generation configs and the seeded token sampler.
//!
//! Every serving request carries a [`GenConfig`]: how to pick the next
//! token from the model's logits (greedy argmax, or seeded
//! temperature / top-k / top-p sampling) and when to stop early (stop
//! token ids). The continuous scheduler
//! ([`crate::coordinator::scheduler`]) builds one [`Sampler`] per
//! admitted request from its config and consults it at every
//! token-selection point.
//!
//! The **default config is greedy argmax** (`temperature == 0`), and the
//! greedy path calls [`crate::util::argmax`] directly — no RNG draw, no
//! float massaging — so every bit-parity pin in the repo (sequential ==
//! lockstep == continuous == paged) survives sampling support untouched.
//! Non-greedy selection is still fully deterministic given the config's
//! `seed`: the sampler owns a private xoshiro256** stream
//! ([`crate::util::rng::Rng`]) seeded from it, one draw per token.
//!
//! Selection order (the conventional pipeline): scale logits by
//! `1/temperature`, keep the `top_k` highest (0 = all), keep the
//! smallest probability-ranked prefix whose mass reaches `top_p`
//! (1.0 = all), renormalize, sample. Ties rank by lower token id first,
//! so candidate order — and therefore the sampled stream — is
//! deterministic even with equal logits.

use crate::util::argmax;
use crate::util::rng::Rng;

/// Per-request generation config, carried on the wire and on
/// [`crate::coordinator::batcher::Request`]. The default is greedy
/// argmax with no stop tokens — bit-identical to every pre-sampling
/// serving path.
#[derive(Clone, Debug, PartialEq)]
pub struct GenConfig {
    /// Softmax temperature. `0` (the default) means **greedy argmax** —
    /// no randomness at all; values `> 0` enable seeded sampling.
    pub temperature: f32,
    /// Keep only the `top_k` highest-logit tokens before sampling.
    /// `0` = no top-k cut.
    pub top_k: usize,
    /// Nucleus cutoff: keep the smallest probability-ranked prefix with
    /// cumulative mass `>= top_p`. `1.0` = no nucleus cut.
    pub top_p: f32,
    /// Seed for this request's private sampling stream. Two requests
    /// with identical prompt + config produce identical tokens.
    pub seed: u64,
    /// Stop token ids: generation halts as soon as one is *produced*
    /// (the stop token is emitted and marked final; the remaining `gen`
    /// budget is abandoned).
    pub stop: Vec<u16>,
}

impl Default for GenConfig {
    fn default() -> Self {
        Self {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
            stop: Vec::new(),
        }
    }
}

impl GenConfig {
    /// Greedy configs take the exact argmax path (no RNG construction
    /// cost, no float scaling) — the serving default.
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    /// Reject configs that cannot select a token sensibly: non-finite or
    /// negative temperature, or a `top_p` outside `(0, 1]`.
    pub fn validate(&self) -> Result<(), String> {
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            return Err(format!(
                "temperature must be a finite value >= 0, got {}",
                self.temperature
            ));
        }
        if !(self.top_p > 0.0 && self.top_p <= 1.0) {
            return Err(format!("top_p must be in (0, 1], got {}", self.top_p));
        }
        Ok(())
    }

    /// Build this config's per-request [`Sampler`] (seeds the private
    /// RNG stream).
    pub fn sampler(&self) -> Sampler {
        Sampler::new(self.clone())
    }
}

/// One request's token selector: the [`GenConfig`] plus its private
/// seeded RNG stream. The scheduler holds one per in-flight slot and
/// calls [`select`](Self::select) wherever it previously took a bare
/// argmax.
#[derive(Clone, Debug)]
pub struct Sampler {
    cfg: GenConfig,
    rng: Rng,
}

impl Sampler {
    pub fn new(cfg: GenConfig) -> Self {
        let rng = Rng::new(cfg.seed);
        Self { cfg, rng }
    }

    /// The generation config this sampler was built from. The scheduler
    /// uses it to rebuild a preempted request (the sampler itself —
    /// cloned with its RNG state — carries the mid-stream pick sequence).
    pub fn config(&self) -> &GenConfig {
        &self.cfg
    }

    /// Whether `token` is one of this request's stop ids.
    pub fn is_stop(&self, token: u16) -> bool {
        self.cfg.stop.contains(&token)
    }

    /// Whether this request selects greedily (see
    /// [`GenConfig::is_greedy`]). The scheduler's speculative path is
    /// gated on this: only greedy requests are drafted, because only the
    /// argmax acceptance rule is provably token-identical to plain
    /// decode — sampled requests fall back to the single-token step.
    pub fn is_greedy(&self) -> bool {
        self.cfg.is_greedy()
    }

    /// Pick the next token from `logits`. Greedy configs return
    /// `argmax(logits)` exactly (first index on ties) and consume no
    /// randomness; sampling configs draw once from the private stream.
    pub fn select(&mut self, logits: &[f32]) -> u16 {
        if self.cfg.is_greedy() {
            return argmax(logits) as u16;
        }
        sample_logits(
            logits,
            self.cfg.temperature,
            self.cfg.top_k,
            self.cfg.top_p,
            &mut self.rng,
        ) as u16
    }
}

/// Temperature / top-k / top-p sampling over raw logits, one RNG draw.
/// Exposed as a free function so the filtering math is unit-testable on
/// hand-built logit vectors without a model in sight.
pub fn sample_logits(
    logits: &[f32],
    temperature: f32,
    top_k: usize,
    top_p: f32,
    rng: &mut Rng,
) -> usize {
    assert!(!logits.is_empty(), "cannot sample from empty logits");
    // Candidates ranked by logit descending; equal logits rank by lower
    // index so the candidate order is deterministic.
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| {
        logits[b]
            .partial_cmp(&logits[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    if top_k > 0 && top_k < idx.len() {
        idx.truncate(top_k);
    }
    // Max-subtracted softmax over the survivors at the given
    // temperature; idx[0] holds the largest surviving logit.
    let t = f64::from(temperature.max(1e-6));
    let m = f64::from(logits[idx[0]]);
    let mut probs: Vec<f64> = idx
        .iter()
        .map(|&i| ((f64::from(logits[i]) - m) / t).exp())
        .collect();
    let sum: f64 = probs.iter().sum();
    for p in &mut probs {
        *p /= sum;
    }
    // Nucleus cut: probs are already descending (same order as idx), so
    // the nucleus is the shortest prefix reaching top_p mass. At least
    // one candidate always survives.
    if top_p < 1.0 {
        let mut cum = 0.0;
        let mut keep = probs.len();
        for (i, &p) in probs.iter().enumerate() {
            cum += p;
            if cum >= f64::from(top_p) {
                keep = i + 1;
                break;
            }
        }
        idx.truncate(keep);
        probs.truncate(keep);
    }
    let mass: f64 = probs.iter().sum();
    let mut x = rng.f64() * mass;
    for (&i, &p) in idx.iter().zip(probs.iter()) {
        x -= p;
        if x <= 0.0 {
            return i;
        }
    }
    *idx.last().expect("nonempty candidate set")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_greedy_and_bit_identical_to_argmax() {
        let cfg = GenConfig::default();
        assert!(cfg.is_greedy());
        let mut sampler = cfg.sampler();
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let logits: Vec<f32> = (0..32).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            assert_eq!(sampler.select(&logits) as usize, argmax(&logits));
        }
        // ties resolve to the first index, exactly like argmax
        assert_eq!(sampler.select(&[1.0, 3.0, 3.0]), 1);
    }

    #[test]
    fn low_temperature_concentrates_high_temperature_spreads() {
        // logits [0, 4]: at temperature 0.25 the gap is 16 nats — the
        // top token wins every draw; at temperature 8 the gap is 0.5
        // nats and both tokens must appear.
        let logits = [0.0f32, 4.0];
        let mut rng = Rng::new(5);
        for _ in 0..1000 {
            assert_eq!(sample_logits(&logits, 0.25, 0, 1.0, &mut rng), 1);
        }
        let mut counts = [0usize; 2];
        for _ in 0..2000 {
            counts[sample_logits(&logits, 8.0, 0, 1.0, &mut rng)] += 1;
        }
        // p(token 0) = 1 / (1 + e^0.5) ~= 0.378; expect ~755 of 2000
        assert!(
            (600..=900).contains(&counts[0]),
            "temperature 8 should leave both tokens live, got {counts:?}"
        );
    }

    #[test]
    fn top_k_filters_to_the_k_highest_logits() {
        let logits = [5.0f32, 4.0, 3.0, 2.0, 1.0];
        let mut rng = Rng::new(7);
        let mut counts = [0usize; 5];
        for _ in 0..500 {
            counts[sample_logits(&logits, 1.0, 2, 1.0, &mut rng)] += 1;
        }
        assert_eq!(counts[2] + counts[3] + counts[4], 0, "top-k 2 leaked: {counts:?}");
        // p(token 1 | top-2) = 1 / (1 + e) ~= 0.27 — both survivors appear
        assert!(counts[0] > 0 && counts[1] > 0, "both top-2 tokens should appear: {counts:?}");
    }

    #[test]
    fn top_p_keeps_the_smallest_prefix_reaching_the_mass() {
        // Logits built as ln(p): softmax at temperature 1 recovers
        // exactly p = [0.5, 0.3, 0.15, 0.05]. top_p 0.75 keeps {0, 1}
        // (cumulative 0.5 then 0.8 >= 0.75) and nothing else.
        let logits: Vec<f32> = [0.5f32, 0.3, 0.15, 0.05].iter().map(|p| p.ln()).collect();
        let mut rng = Rng::new(11);
        let mut counts = [0usize; 4];
        for _ in 0..500 {
            counts[sample_logits(&logits, 1.0, 0, 0.75, &mut rng)] += 1;
        }
        assert_eq!(counts[2] + counts[3], 0, "nucleus leaked: {counts:?}");
        // renormalized p(token 1) = 0.3 / 0.8 = 0.375 — it must appear
        assert!(counts[0] > 0 && counts[1] > 0, "both nucleus tokens should appear: {counts:?}");
    }

    #[test]
    fn sampling_is_deterministic_from_the_config_seed() {
        let cfg = GenConfig {
            temperature: 1.3,
            top_k: 8,
            top_p: 0.9,
            seed: 42,
            stop: Vec::new(),
        };
        let mut rng = Rng::new(3);
        let logit_rows: Vec<Vec<f32>> =
            (0..40).map(|_| (0..24).map(|_| rng.normal_f32(0.0, 2.0)).collect()).collect();
        let run = |cfg: &GenConfig| -> Vec<u16> {
            let mut s = cfg.sampler();
            logit_rows.iter().map(|l| s.select(l)).collect()
        };
        assert_eq!(run(&cfg), run(&cfg), "same seed must replay the same tokens");
        let other = GenConfig { seed: 43, ..cfg.clone() };
        assert_ne!(run(&cfg), run(&other), "different seeds should diverge");
    }

    #[test]
    fn stop_membership_checks_the_config_list() {
        let cfg = GenConfig {
            stop: vec![3, 17],
            ..GenConfig::default()
        };
        let s = cfg.sampler();
        assert!(s.is_stop(3));
        assert!(s.is_stop(17));
        assert!(!s.is_stop(4));
        assert!(!GenConfig::default().sampler().is_stop(0));
    }

    #[test]
    fn validate_rejects_bad_temperature_and_top_p() {
        assert!(GenConfig::default().validate().is_ok());
        let bad_t = GenConfig { temperature: f32::NAN, ..GenConfig::default() };
        assert!(bad_t.validate().is_err());
        let neg_t = GenConfig { temperature: -1.0, ..GenConfig::default() };
        assert!(neg_t.validate().is_err());
        let bad_p = GenConfig { top_p: 0.0, ..GenConfig::default() };
        assert!(bad_p.validate().is_err());
        let nan_p = GenConfig { top_p: f32::NAN, ..GenConfig::default() };
        assert!(nan_p.validate().is_err());
    }
}
