//! LLaMA-like transformer inference stack with pluggable quantized
//! linears.
//!
//! Architecture (matching the paper's LLAMA target and Figure 2's BWA
//! attention): token embedding → N × [RMSNorm → MHA(RoPE, INT4 KV) →
//! residual → RMSNorm → SwiGLU MLP → residual] → RMSNorm → LM head.
//!
//! Every projection (`wq wk wv wo gate up down`) is a [`CompiledLinear`]:
//! the quantized *storage* form (`Box<dyn QuantLinear>`, kept for size /
//! bit accounting and the fake-quant reference path) plus its compiled
//! *execution plan* (`Box<dyn LinearExec>`). The hot paths
//! ([`Transformer::forward`], [`Transformer::decode_step`]) run the
//! execution plans with preallocated output buffers and prepare each
//! shared input **once**: wq/wk/wv consume one [`PreparedActs`], gate/up
//! another — for the paper's method that means one activation
//! quantize+pack feeding three popcount GEMMs. Embedding and LM head
//! stay FP (standard PTQ practice, also what the baselines in the paper
//! do). [`Transformer::forward_reference`] keeps the old dense
//! fake-quant route for parity tests and benches.
//!
//! Serving splits a request into [`Transformer::prefill`] (one batch
//! forward that fills the session's INT4 KV caches) followed by
//! [`Transformer::decode_step`] or — for many sequences in lockstep —
//! [`Transformer::decode_step_batch`], which packs the whole batch's
//! activations once per shared input and runs M = batch popcount GEMMs.
//! All three agree with each other to the bit (parity tests below);
//! the coordinator's engine ([`crate::coordinator::ParallelBackend`])
//! drives them across a worker pool.
//!
//! The serving forwards carry per-op profiling scopes
//! ([`crate::obs::profile::op_scope`]) around every projection, the
//! shared activation pack, attention, and the norms — inert (no clock
//! read) unless `profile::set_enabled(true)` opted in. `wo`/`down` run
//! through `forward_into` on the single-row paths, so their scopes
//! include the op's own activation pack; the explicitly shared packs
//! (wq/wk/wv, gate/up) are attributed to `pack`.

pub mod checkpoint;
pub mod config;
pub mod kv_cache;
pub mod sampling;

use crate::kvpool::{BlockPool, PrefixMatch};
use crate::model::checkpoint::{Checkpoint, CkptError};
use crate::model::config::ModelConfig;
use crate::model::kv_cache::{Kv4Store, LayerKvCache};
use crate::obs::profile::{self, Op};
use crate::quant::{
    FpLinear, LayerCtx, LinearExec, LinearKind, QuantError, QuantLinear, Quantizer,
};
use crate::tensor::Tensor;
use crate::util::pool::parallel_map;
use crate::util::rng::Rng;
use crate::util::softmax_inplace;
use std::sync::Arc;

/// RMSNorm with learned gain.
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f64, out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / (ms + eps).sqrt() as f32;
    for i in 0..x.len() {
        out[i] = x[i] * inv * gain[i];
    }
}

/// Rotary position embedding applied in place to one [T, d] tensor with
/// `n_heads` heads (pairs rotated within each head).
pub fn apply_rope(x: &mut Tensor, n_heads: usize, theta: f64, pos_offset: usize) {
    let (t_len, _) = x.dims2();
    for t in 0..t_len {
        apply_rope_row(x.row_mut(t), n_heads, theta, t + pos_offset);
    }
}

/// RoPE for a single `[d]` row at absolute position `pos` — the batched
/// decode path rotates each sequence's row at its own position.
pub fn apply_rope_row(row: &mut [f32], n_heads: usize, theta: f64, pos: usize) {
    let hd = row.len() / n_heads;
    let pos = pos as f64;
    for h in 0..n_heads {
        let base = h * hd;
        for i in 0..hd / 2 {
            let freq = 1.0 / theta.powf(2.0 * i as f64 / hd as f64);
            let angle = pos * freq;
            let (sin, cos) = (angle.sin() as f32, angle.cos() as f32);
            let a = row[base + 2 * i];
            let b = row[base + 2 * i + 1];
            row[base + 2 * i] = a * cos - b * sin;
            row[base + 2 * i + 1] = a * sin + b * cos;
        }
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Errors from building a quantized model: checkpoint I/O or per-layer
/// quantization failure.
#[derive(Debug)]
pub enum ModelError {
    Ckpt(CkptError),
    Quant(QuantError),
}

impl std::fmt::Display for ModelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Ckpt(e) => write!(f, "{e}"),
            Self::Quant(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ModelError {}

impl From<CkptError> for ModelError {
    fn from(e: CkptError) -> Self {
        Self::Ckpt(e)
    }
}

impl From<QuantError> for ModelError {
    fn from(e: QuantError) -> Self {
        Self::Quant(e)
    }
}

/// A quantized linear plus its compiled execution plan. The plan serves
/// the hot path; the storage form answers size/bit queries and provides
/// the dense fake-quant reference forward.
///
/// Memory note: the storage form keeps the dense `w_hat` (needed by
/// [`Transformer::forward_reference`] and reported-size accounting) and
/// the plan owns its own copy of the packed structures, so a compiled
/// model trades memory for having both paths resident. A deploy-only
/// build that drops the reference path could share the bit structures
/// via `Arc` — deliberately not done while parity tests are the main
/// consumer.
pub struct CompiledLinear {
    pub quant: Box<dyn QuantLinear>,
    pub exec: Box<dyn LinearExec>,
}

impl CompiledLinear {
    pub fn new(quant: Box<dyn QuantLinear>) -> Self {
        let exec = quant.compile();
        Self { quant, exec }
    }

    /// Convenience allocating forward through the compiled plan.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let (t_len, _) = x.dims2();
        let mut out = Tensor::zeros(&[t_len, self.exec.out_features()]);
        self.exec.forward_into(x, &mut out);
        out
    }
}

/// Multi-head attention block.
pub struct Attention {
    pub wq: CompiledLinear,
    pub wk: CompiledLinear,
    pub wv: CompiledLinear,
    pub wo: CompiledLinear,
}

/// SwiGLU MLP block.
pub struct Mlp {
    pub gate: CompiledLinear,
    pub up: CompiledLinear,
    pub down: CompiledLinear,
}

pub struct Block {
    pub attn_norm: Vec<f32>,
    pub attn: Attention,
    pub mlp_norm: Vec<f32>,
    pub mlp: Mlp,
}

pub struct Transformer {
    pub cfg: ModelConfig,
    pub embed: Tensor,
    pub blocks: Vec<Block>,
    pub final_norm: Vec<f32>,
    pub lm_head: Tensor,
    /// KV quantization bits (None = FP cache; Some(4) for quantized runs).
    pub kv_bits: Option<u32>,
}

/// Core of causal batch attention given q/k/v [T, d]: per-head causal
/// softmax(q·kᵀ/√hd)·v. K/V are already (fake-)quantized by the caller.
pub fn causal_attention(q: &Tensor, k: &Tensor, v: &Tensor, n_heads: usize) -> Tensor {
    let (t_len, d) = q.dims2();
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Tensor::zeros(&[t_len, d]);
    let mut scores = vec![0.0f32; t_len];
    for h in 0..n_heads {
        let base = h * hd;
        for tq in 0..t_len {
            let qrow = &q.row(tq)[base..base + hd];
            for tk in 0..=tq {
                let krow = &k.row(tk)[base..base + hd];
                let mut s = 0.0f32;
                for i in 0..hd {
                    s += qrow[i] * krow[i];
                }
                scores[tk] = s * scale;
            }
            softmax_inplace(&mut scores[..=tq]);
            let orow = &mut out.row_mut(tq)[base..base + hd];
            for tk in 0..=tq {
                let w = scores[tk];
                let vrow = &v.row(tk)[base..base + hd];
                for i in 0..hd {
                    orow[i] += w * vrow[i];
                }
            }
        }
    }
    out
}

/// [`causal_attention`] for **suffix** queries over fully materialized
/// K/V rows covering positions `[0, pos_offset + T)` — the warm-prefill
/// inner loop ([`Transformer::prefill_suffix_with`]). Query row `tq`
/// sits at absolute position `pos_offset + tq` and attends causally over
/// all earlier rows of `k`/`v` (flat `[pos_offset + T, d]`, row-major —
/// here: the session's KV cache dequantized once per layer). With
/// `pos_offset == 0` and identical K/V values this computes exactly
/// [`causal_attention`], loop order and all, so the cold and warm
/// prefill paths are bit-identical (test-pinned).
fn causal_attention_cached(
    q: &Tensor,
    k: &[f32],
    v: &[f32],
    n_heads: usize,
    pos_offset: usize,
) -> Tensor {
    let (t_len, d) = q.dims2();
    debug_assert_eq!(k.len(), (pos_offset + t_len) * d);
    debug_assert_eq!(v.len(), (pos_offset + t_len) * d);
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Tensor::zeros(&[t_len, d]);
    let mut scores = vec![0.0f32; pos_offset + t_len];
    for h in 0..n_heads {
        let base = h * hd;
        for tq in 0..t_len {
            let abs = pos_offset + tq;
            let qrow = &q.row(tq)[base..base + hd];
            for tk in 0..=abs {
                let krow = &k[tk * d + base..tk * d + base + hd];
                let mut s = 0.0f32;
                for i in 0..hd {
                    s += qrow[i] * krow[i];
                }
                scores[tk] = s * scale;
            }
            softmax_inplace(&mut scores[..=abs]);
            let orow = &mut out.row_mut(tq)[base..base + hd];
            for tk in 0..=abs {
                let w = scores[tk];
                let vrow = &v[tk * d + base..tk * d + base + hd];
                for i in 0..hd {
                    orow[i] += w * vrow[i];
                }
            }
        }
    }
    out
}

/// One query row attending over a layer's quantized KV cache — the inner
/// loop of incremental decoding, shared by [`Transformer::decode_step`]
/// and [`Transformer::decode_step_batch`] so the single-sequence and
/// batched paths run bit-identical math. `scores`/`kbuf`/`vbuf` are
/// caller-owned scratch, grown to the cache length as it fills; each
/// cached row is INT4-dequantized **once** per step into `kbuf`/`vbuf`
/// rather than once per head.
fn attend_over_cache(
    cache: &LayerKvCache,
    q: &[f32],
    out: &mut [f32],
    n_heads: usize,
    scores: &mut Vec<f32>,
    kbuf: &mut Vec<f32>,
    vbuf: &mut Vec<f32>,
) {
    let d = q.len();
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let t_len = cache.len();
    scores.resize(t_len, 0.0);
    kbuf.resize(t_len * d, 0.0);
    vbuf.resize(t_len * d, 0.0);
    for t in 0..t_len {
        cache.k.get(t, &mut kbuf[t * d..(t + 1) * d]);
        cache.v.get(t, &mut vbuf[t * d..(t + 1) * d]);
    }
    for val in out.iter_mut() {
        *val = 0.0;
    }
    for hh in 0..n_heads {
        let base = hh * hd;
        for t in 0..t_len {
            let krow = &kbuf[t * d..(t + 1) * d];
            let qh = &q[base..base + hd];
            let mut s = 0.0f32;
            for i in 0..hd {
                s += qh[i] * krow[base + i];
            }
            scores[t] = s * scale;
        }
        softmax_inplace(scores);
        for t in 0..t_len {
            let vrow = &vbuf[t * d..(t + 1) * d];
            let w = scores[t];
            for i in 0..hd {
                out[base + i] += w * vrow[base + i];
            }
        }
    }
}

impl Transformer {
    /// Random FP model (tests and micro-benches).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Transformer {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let std = 0.08;
        let lin = |rng: &mut Rng, o: usize, i: usize| -> CompiledLinear {
            CompiledLinear::new(Box::new(FpLinear {
                w: Tensor::from_vec(&[o, i], rng.normal_vec_f32(o * i, 0.0, std)),
            }))
        };
        let blocks = (0..cfg.n_layers)
            .map(|_| Block {
                attn_norm: vec![1.0; d],
                attn: Attention {
                    wq: lin(&mut rng, d, d),
                    wk: lin(&mut rng, d, d),
                    wv: lin(&mut rng, d, d),
                    wo: lin(&mut rng, d, d),
                },
                mlp_norm: vec![1.0; d],
                mlp: Mlp {
                    gate: lin(&mut rng, cfg.d_ff, d),
                    up: lin(&mut rng, cfg.d_ff, d),
                    down: lin(&mut rng, d, cfg.d_ff),
                },
            })
            .collect();
        Transformer {
            cfg: cfg.clone(),
            embed: Tensor::from_vec(
                &[cfg.vocab_size, d],
                rng.normal_vec_f32(cfg.vocab_size * d, 0.0, 0.5),
            ),
            blocks,
            final_norm: vec![1.0; d],
            lm_head: Tensor::from_vec(
                &[cfg.vocab_size, d],
                rng.normal_vec_f32(cfg.vocab_size * d, 0.0, std),
            ),
            kv_bits: None,
        }
    }

    /// FP model from a trainer checkpoint.
    pub fn fp_from_checkpoint(ck: &Checkpoint) -> Result<Transformer, CkptError> {
        let cfg = ck.config.clone();
        let lin = |name: &str| -> Result<CompiledLinear, CkptError> {
            Ok(CompiledLinear::new(Box::new(FpLinear {
                w: ck.get(name)?.clone(),
            })))
        };
        let mut blocks = Vec::new();
        for l in 0..cfg.n_layers {
            blocks.push(Block {
                attn_norm: ck.get(&format!("layers.{l}.attn_norm"))?.data.clone(),
                attn: Attention {
                    wq: lin(&format!("layers.{l}.wq"))?,
                    wk: lin(&format!("layers.{l}.wk"))?,
                    wv: lin(&format!("layers.{l}.wv"))?,
                    wo: lin(&format!("layers.{l}.wo"))?,
                },
                mlp_norm: ck.get(&format!("layers.{l}.mlp_norm"))?.data.clone(),
                mlp: Mlp {
                    gate: lin(&format!("layers.{l}.gate"))?,
                    up: lin(&format!("layers.{l}.up"))?,
                    down: lin(&format!("layers.{l}.down"))?,
                },
            });
        }
        Ok(Transformer {
            cfg: cfg.clone(),
            embed: ck.get("embed")?.clone(),
            blocks,
            final_norm: ck.get("final_norm")?.data.clone(),
            lm_head: ck.get("lm_head")?.clone(),
            kv_bits: None,
        })
    }

    fn norm_all(&self, x: &Tensor, gain: &[f32]) -> Tensor {
        let (t_len, d) = x.dims2();
        let mut out = Tensor::zeros(&[t_len, d]);
        self.norm_all_into(x, gain, &mut out);
        out
    }

    fn norm_all_into(&self, x: &Tensor, gain: &[f32], out: &mut Tensor) {
        let (t_len, _) = x.dims2();
        debug_assert_eq!(x.shape, out.shape);
        for t in 0..t_len {
            rmsnorm(x.row(t), gain, self.cfg.rmsnorm_eps, out.row_mut(t));
        }
    }

    fn maybe_kv_quant(&self, x: &mut Tensor) {
        if let Some(bits) = self.kv_bits {
            debug_assert_eq!(bits, 4, "only INT4 KV supported");
            let (t_len, _) = x.dims2();
            for t in 0..t_len {
                Kv4Store::fake_quantize(x.row_mut(t));
            }
        }
    }

    /// Batch forward: logits [T, vocab] for a token sequence (causal).
    ///
    /// Runs the compiled execution plans (the packed popcount kernel for
    /// the paper's method) with per-call preallocated buffers; each
    /// shared input is prepared once (wq/wk/wv together, gate/up
    /// together).
    pub fn forward(&self, tokens: &[u16]) -> Tensor {
        let t_len = tokens.len();
        let d = self.cfg.d_model;
        let d_ff = self.cfg.d_ff;
        assert!(t_len <= self.cfg.max_seq, "sequence longer than max_seq");
        let mut x = Tensor::zeros(&[t_len, d]);
        for (t, &tok) in tokens.iter().enumerate() {
            x.row_mut(t).copy_from_slice(self.embed.row(tok as usize));
        }
        // preallocated output buffers, reused across blocks
        let mut h = Tensor::zeros(&[t_len, d]);
        let mut q = Tensor::zeros(&[t_len, d]);
        let mut k = Tensor::zeros(&[t_len, d]);
        let mut v = Tensor::zeros(&[t_len, d]);
        let mut o = Tensor::zeros(&[t_len, d]);
        let mut g = Tensor::zeros(&[t_len, d_ff]);
        let mut u = Tensor::zeros(&[t_len, d_ff]);
        let mut dwn = Tensor::zeros(&[t_len, d]);
        for blk in &self.blocks {
            // attention — one prepared input feeds wq/wk/wv
            self.norm_all_into(&x, &blk.attn_norm, &mut h);
            {
                let acts = blk.attn.wq.exec.prepare(&h);
                blk.attn.wq.exec.forward_prepared(&acts, &mut q);
                blk.attn.wk.exec.forward_prepared(&acts, &mut k);
                blk.attn.wv.exec.forward_prepared(&acts, &mut v);
            }
            apply_rope(&mut q, self.cfg.n_heads, self.cfg.rope_theta, 0);
            apply_rope(&mut k, self.cfg.n_heads, self.cfg.rope_theta, 0);
            self.maybe_kv_quant(&mut k);
            self.maybe_kv_quant(&mut v);
            let attn_out = causal_attention(&q, &k, &v, self.cfg.n_heads);
            blk.attn.wo.exec.forward_into(&attn_out, &mut o);
            for i in 0..x.data.len() {
                x.data[i] += o.data[i];
            }
            // mlp — gate/up share one prepared input
            self.norm_all_into(&x, &blk.mlp_norm, &mut h);
            {
                let acts = blk.mlp.gate.exec.prepare(&h);
                blk.mlp.gate.exec.forward_prepared(&acts, &mut g);
                blk.mlp.up.exec.forward_prepared(&acts, &mut u);
            }
            for i in 0..g.data.len() {
                g.data[i] = silu(g.data[i]) * u.data[i];
            }
            blk.mlp.down.exec.forward_into(&g, &mut dwn);
            for i in 0..x.data.len() {
                x.data[i] += dwn.data[i];
            }
        }
        let xn = self.norm_all(&x, &self.final_norm);
        crate::kernels::dense::sgemm_wt(&xn, &self.lm_head)
    }

    /// Reference batch forward through the *storage* forms
    /// ([`QuantLinear::forward`] — the dense fake-quant math). Kept for
    /// parity tests and the fake-vs-packed model bench; the serving path
    /// is [`Self::forward`].
    pub fn forward_reference(&self, tokens: &[u16]) -> Tensor {
        let t_len = tokens.len();
        let d = self.cfg.d_model;
        assert!(t_len <= self.cfg.max_seq, "sequence longer than max_seq");
        let mut x = Tensor::zeros(&[t_len, d]);
        for (t, &tok) in tokens.iter().enumerate() {
            x.row_mut(t).copy_from_slice(self.embed.row(tok as usize));
        }
        for blk in &self.blocks {
            let h = self.norm_all(&x, &blk.attn_norm);
            let mut q = blk.attn.wq.quant.forward(&h);
            let mut k = blk.attn.wk.quant.forward(&h);
            let mut v = blk.attn.wv.quant.forward(&h);
            apply_rope(&mut q, self.cfg.n_heads, self.cfg.rope_theta, 0);
            apply_rope(&mut k, self.cfg.n_heads, self.cfg.rope_theta, 0);
            self.maybe_kv_quant(&mut k);
            self.maybe_kv_quant(&mut v);
            let attn_out = causal_attention(&q, &k, &v, self.cfg.n_heads);
            let o = blk.attn.wo.quant.forward(&attn_out);
            for i in 0..x.data.len() {
                x.data[i] += o.data[i];
            }
            let h = self.norm_all(&x, &blk.mlp_norm);
            let g = blk.mlp.gate.quant.forward(&h);
            let u = blk.mlp.up.quant.forward(&h);
            let mut act = Tensor::zeros(&[t_len, self.cfg.d_ff]);
            for i in 0..act.data.len() {
                act.data[i] = silu(g.data[i]) * u.data[i];
            }
            let dwn = blk.mlp.down.quant.forward(&act);
            for i in 0..x.data.len() {
                x.data[i] += dwn.data[i];
            }
        }
        let xn = self.norm_all(&x, &self.final_norm);
        crate::kernels::dense::sgemm_wt(&xn, &self.lm_head)
    }

    /// Start an incremental decoding session (per-layer INT4 KV caches +
    /// preallocated per-step scratch buffers).
    pub fn new_session(&self) -> DecodeSession {
        self.new_session_with_capacity(0)
    }

    /// [`Self::new_session`] with **contiguous** KV-cache storage
    /// reserved for `tokens` positions up front — lockstep serving knows
    /// `prompt + gen` when a request arrives and pays each request's
    /// worst case privately, so that `Vec` never reallocates
    /// mid-request. The continuous scheduler instead uses the paged
    /// backing ([`Self::new_session_paged`] /
    /// [`Self::new_session_from_prefix`]): fixed-size blocks allocated
    /// on demand from a shared [`BlockPool`], bit-identical rows, and
    /// shared-prefix reuse across requests.
    pub fn new_session_with_capacity(&self, tokens: usize) -> DecodeSession {
        let d = self.cfg.d_model;
        self.session_with_caches(
            (0..self.cfg.n_layers)
                .map(|_| LayerKvCache::with_capacity(d, tokens))
                .collect(),
            0,
        )
    }

    /// Session whose per-layer KV caches allocate fixed-size blocks from
    /// `pool` instead of private contiguous `Vec`s — same bits, shared
    /// budget (see [`crate::kvpool`]).
    pub fn new_session_paged(&self, pool: &Arc<BlockPool>) -> DecodeSession {
        let d = self.cfg.d_model;
        self.session_with_caches(
            (0..self.cfg.n_layers).map(|_| LayerKvCache::paged(d, pool)).collect(),
            0,
        )
    }

    /// Paged session seeded with an adopted cached prefix: the caches
    /// start at `prefix.rows` rows of shared blocks and `pos` is set to
    /// match, so [`Self::prefill_suffix_with`] computes only the
    /// remaining prompt tokens. An empty match yields a fresh paged
    /// session.
    pub fn new_session_from_prefix(
        &self,
        pool: &Arc<BlockPool>,
        prefix: PrefixMatch,
    ) -> DecodeSession {
        if prefix.rows == 0 {
            return self.new_session_paged(pool);
        }
        assert_eq!(
            prefix.layers.len(),
            self.cfg.n_layers,
            "prefix match must cover every layer"
        );
        let d = self.cfg.d_model;
        let rows = prefix.rows;
        self.session_with_caches(
            prefix
                .layers
                .into_iter()
                .map(|(ks, vs)| LayerKvCache::paged_from_prefix(d, pool, ks, vs, rows))
                .collect(),
            rows,
        )
    }

    fn session_with_caches(&self, caches: Vec<LayerKvCache>, pos: usize) -> DecodeSession {
        let d = self.cfg.d_model;
        let d_ff = self.cfg.d_ff;
        DecodeSession {
            caches,
            pos,
            reserved_blocks: 0,
            scratch: DecodeScratch {
                x: vec![0.0; d],
                h: Tensor::zeros(&[1, d]),
                q: Tensor::zeros(&[1, d]),
                k: Tensor::zeros(&[1, d]),
                v: Tensor::zeros(&[1, d]),
                attn_out: Tensor::zeros(&[1, d]),
                o: Tensor::zeros(&[1, d]),
                g: Tensor::zeros(&[1, d_ff]),
                u: Tensor::zeros(&[1, d_ff]),
                dwn: Tensor::zeros(&[1, d]),
                krow: vec![0.0; d],
                vrow: vec![0.0; d],
                scores: Vec::new(),
            },
        }
    }

    /// Feed one token; returns logits `[vocab]` for the next position.
    /// Uses the INT4 KV cache — the serving path — running the compiled
    /// execution plans into the session's preallocated scratch buffers
    /// (one activation preparation for wq/wk/wv, one for gate/up). For FP
    /// models the cache still quantizes to INT4 when `kv_bits` is set,
    /// else stores FP equivalents via 16-bit-exact round trip (here:
    /// quantized always, to keep one cache implementation; FP-cache
    /// equivalence is covered by `kv_bits: Some(4)` tests).
    pub fn decode_step(&self, sess: &mut DecodeSession, token: u16) -> Vec<f32> {
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let pos = sess.pos;
        let scratch = &mut sess.scratch;
        scratch.x.copy_from_slice(self.embed.row(token as usize));

        for (l, blk) in self.blocks.iter().enumerate() {
            {
                let _p = profile::op_scope(Op::Norm, l, 1, 0);
                rmsnorm(
                    &scratch.x,
                    &blk.attn_norm,
                    self.cfg.rmsnorm_eps,
                    scratch.h.row_mut(0),
                );
            }
            {
                let acts = {
                    let _p = profile::op_scope(Op::Pack, l, 1, 0);
                    blk.attn.wq.exec.prepare(&scratch.h)
                };
                {
                    let _p = profile::op_scope(Op::Wq, l, 1, blk.attn.wq.exec.plane_bytes());
                    blk.attn.wq.exec.forward_prepared(&acts, &mut scratch.q);
                }
                {
                    let _p = profile::op_scope(Op::Wk, l, 1, blk.attn.wk.exec.plane_bytes());
                    blk.attn.wk.exec.forward_prepared(&acts, &mut scratch.k);
                }
                {
                    let _p = profile::op_scope(Op::Wv, l, 1, blk.attn.wv.exec.plane_bytes());
                    blk.attn.wv.exec.forward_prepared(&acts, &mut scratch.v);
                }
            }
            apply_rope(&mut scratch.q, nh, self.cfg.rope_theta, pos);
            apply_rope(&mut scratch.k, nh, self.cfg.rope_theta, pos);
            {
                let _p = profile::op_scope(Op::Attn, l, 1, 0);
                let cache = &mut sess.caches[l];
                cache.k.push(scratch.k.row(0));
                cache.v.push(scratch.v.row(0));
                // per-head attention over the quantized cache
                attend_over_cache(
                    cache,
                    scratch.q.row(0),
                    scratch.attn_out.row_mut(0),
                    nh,
                    &mut scratch.scores,
                    &mut scratch.krow,
                    &mut scratch.vrow,
                );
            }
            {
                let _p = profile::op_scope(Op::Wo, l, 1, blk.attn.wo.exec.plane_bytes());
                blk.attn.wo.exec.forward_into(&scratch.attn_out, &mut scratch.o);
            }
            for i in 0..d {
                scratch.x[i] += scratch.o.data[i];
            }
            // mlp
            {
                let _p = profile::op_scope(Op::Norm, l, 1, 0);
                rmsnorm(
                    &scratch.x,
                    &blk.mlp_norm,
                    self.cfg.rmsnorm_eps,
                    scratch.h.row_mut(0),
                );
            }
            {
                let acts = {
                    let _p = profile::op_scope(Op::Pack, l, 1, 0);
                    blk.mlp.gate.exec.prepare(&scratch.h)
                };
                {
                    let _p = profile::op_scope(Op::Gate, l, 1, blk.mlp.gate.exec.plane_bytes());
                    blk.mlp.gate.exec.forward_prepared(&acts, &mut scratch.g);
                }
                {
                    let _p = profile::op_scope(Op::Up, l, 1, blk.mlp.up.exec.plane_bytes());
                    blk.mlp.up.exec.forward_prepared(&acts, &mut scratch.u);
                }
            }
            for i in 0..self.cfg.d_ff {
                scratch.g.data[i] = silu(scratch.g.data[i]) * scratch.u.data[i];
            }
            {
                let _p = profile::op_scope(Op::Down, l, 1, blk.mlp.down.exec.plane_bytes());
                blk.mlp.down.exec.forward_into(&scratch.g, &mut scratch.dwn);
            }
            for i in 0..d {
                scratch.x[i] += scratch.dwn.data[i];
            }
        }
        rmsnorm(
            &scratch.x,
            &self.final_norm,
            self.cfg.rmsnorm_eps,
            scratch.h.row_mut(0),
        );
        let logits = crate::kernels::dense::sgemm_wt(&scratch.h, &self.lm_head);
        sess.pos += 1;
        logits.data
    }

    /// Batched prefill: run the full-sequence forward pass **and** fill
    /// the session's per-layer KV caches, returning the last-position
    /// logits `[vocab]`. This is the first phase of serving a request:
    /// one batch forward (compiled popcount execs, shared activation
    /// preparation) instead of `tokens.len()` incremental decode steps,
    /// after which [`Self::decode_step`] / [`Self::decode_step_batch`]
    /// continue from the cache without ever re-running the prompt.
    ///
    /// K/V rows are pushed into the INT4 cache and the in-flight K/V are
    /// fake-quantized to the *same* values before attention, so
    /// `prefill + decode_step` agrees with a pure `decode_step` loop
    /// (asserted by tests). The session must be fresh (`pos == 0`).
    pub fn prefill(&self, sess: &mut DecodeSession, tokens: &[u16]) -> Vec<f32> {
        let mut scratch = PrefillScratch::default();
        self.prefill_with(sess, tokens, &mut scratch)
    }

    /// [`Self::prefill`] with caller-owned scratch buffers — serving
    /// workers keep one [`PrefillScratch`] each and reuse it across every
    /// request they handle, so the linear-layer output and norm buffers
    /// are not reallocated per request. (Attention output and packed
    /// activations are still produced per layer — they are
    /// size-dependent on the prompt and cheap next to the GEMMs.)
    pub fn prefill_with(
        &self,
        sess: &mut DecodeSession,
        tokens: &[u16],
        scratch: &mut PrefillScratch,
    ) -> Vec<f32> {
        let t_len = tokens.len();
        let d = self.cfg.d_model;
        assert!(t_len <= self.cfg.max_seq, "sequence longer than max_seq");
        assert!(t_len > 0, "prefill requires at least one token");
        assert!(
            sess.pos == 0 && sess.caches.iter().all(|c| c.is_empty()),
            "prefill requires a fresh session"
        );
        scratch.ensure(t_len, d, self.cfg.d_ff);
        let x = &mut scratch.x;
        for (t, &tok) in tokens.iter().enumerate() {
            x.row_mut(t).copy_from_slice(self.embed.row(tok as usize));
        }
        for (l, blk) in self.blocks.iter().enumerate() {
            // attention — one prepared input feeds wq/wk/wv
            {
                let _p = profile::op_scope(Op::Norm, l, t_len, 0);
                self.norm_all_into(x, &blk.attn_norm, &mut scratch.h);
            }
            {
                let acts = {
                    let _p = profile::op_scope(Op::Pack, l, t_len, 0);
                    blk.attn.wq.exec.prepare(&scratch.h)
                };
                {
                    let _p = profile::op_scope(Op::Wq, l, t_len, blk.attn.wq.exec.plane_bytes());
                    blk.attn.wq.exec.forward_prepared(&acts, &mut scratch.q);
                }
                {
                    let _p = profile::op_scope(Op::Wk, l, t_len, blk.attn.wk.exec.plane_bytes());
                    blk.attn.wk.exec.forward_prepared(&acts, &mut scratch.k);
                }
                {
                    let _p = profile::op_scope(Op::Wv, l, t_len, blk.attn.wv.exec.plane_bytes());
                    blk.attn.wv.exec.forward_prepared(&acts, &mut scratch.v);
                }
            }
            apply_rope(&mut scratch.q, self.cfg.n_heads, self.cfg.rope_theta, 0);
            apply_rope(&mut scratch.k, self.cfg.n_heads, self.cfg.rope_theta, 0);
            let attn_out = {
                let _p = profile::op_scope(Op::Attn, l, t_len, 0);
                // Push raw post-RoPE rows (the cache quantizes on push),
                // then fake-quantize the in-flight copies to the
                // identical values so prefill attention sees exactly
                // what decode will read.
                let cache = &mut sess.caches[l];
                for t in 0..t_len {
                    cache.k.push(scratch.k.row(t));
                    cache.v.push(scratch.v.row(t));
                    Kv4Store::fake_quantize(scratch.k.row_mut(t));
                    Kv4Store::fake_quantize(scratch.v.row_mut(t));
                }
                causal_attention(&scratch.q, &scratch.k, &scratch.v, self.cfg.n_heads)
            };
            {
                let _p = profile::op_scope(Op::Wo, l, t_len, blk.attn.wo.exec.plane_bytes());
                blk.attn.wo.exec.forward_into(&attn_out, &mut scratch.o);
            }
            for i in 0..x.data.len() {
                x.data[i] += scratch.o.data[i];
            }
            // mlp — gate/up share one prepared input
            {
                let _p = profile::op_scope(Op::Norm, l, t_len, 0);
                self.norm_all_into(x, &blk.mlp_norm, &mut scratch.h);
            }
            {
                let acts = {
                    let _p = profile::op_scope(Op::Pack, l, t_len, 0);
                    blk.mlp.gate.exec.prepare(&scratch.h)
                };
                {
                    let _p = profile::op_scope(Op::Gate, l, t_len, blk.mlp.gate.exec.plane_bytes());
                    blk.mlp.gate.exec.forward_prepared(&acts, &mut scratch.g);
                }
                {
                    let _p = profile::op_scope(Op::Up, l, t_len, blk.mlp.up.exec.plane_bytes());
                    blk.mlp.up.exec.forward_prepared(&acts, &mut scratch.u);
                }
            }
            for i in 0..scratch.g.data.len() {
                scratch.g.data[i] = silu(scratch.g.data[i]) * scratch.u.data[i];
            }
            {
                let _p = profile::op_scope(Op::Down, l, t_len, blk.mlp.down.exec.plane_bytes());
                blk.mlp.down.exec.forward_into(&scratch.g, &mut scratch.dwn);
            }
            for i in 0..x.data.len() {
                x.data[i] += scratch.dwn.data[i];
            }
        }
        sess.pos = t_len;
        // logits only for the last position
        let mut hn = Tensor::zeros(&[1, d]);
        rmsnorm(
            x.row(t_len - 1),
            &self.final_norm,
            self.cfg.rmsnorm_eps,
            hn.row_mut(0),
        );
        crate::kernels::dense::sgemm_wt(&hn, &self.lm_head).data
    }

    /// Warm prefill: run the batch forward for only the **suffix** of
    /// `tokens` that the session's KV caches do not already cover
    /// (`sess.pos` rows — typically an adopted shared prefix from the
    /// [`crate::kvpool::PrefixIndex`]), filling the caches for the
    /// suffix and returning the last-position logits `[vocab]`.
    ///
    /// This is exact, not approximate: causal attention makes prefix KV
    /// a pure function of the prefix tokens, and the cache stores the
    /// already-quantized rows, so attending over reused rows is
    /// bit-identical to recomputing them. With `sess.pos == 0` this *is*
    /// a cold prefill, bit-identical to [`Self::prefill_with`]
    /// (test-pinned) — suffix queries read K/V dequantized from the
    /// cache, which equals the cold path's in-flight fake-quantized
    /// values because `push` + `get` round-trips the same nibbles.
    ///
    /// At least one suffix token is required (the prefix index caps
    /// matches at `prompt_len - 1` for exactly this reason): logits come
    /// from the final token's forward pass.
    pub fn prefill_suffix_with(
        &self,
        sess: &mut DecodeSession,
        tokens: &[u16],
        scratch: &mut PrefillScratch,
    ) -> Vec<f32> {
        assert!(sess.pos < tokens.len(), "suffix prefill needs at least one uncached token");
        let t_len = self.prefill_suffix_body(sess, &tokens[sess.pos..], scratch);
        let d = self.cfg.d_model;
        // logits only for the last position
        let mut hn = Tensor::zeros(&[1, d]);
        rmsnorm(
            scratch.x.row(t_len - 1),
            &self.final_norm,
            self.cfg.rmsnorm_eps,
            hn.row_mut(0),
        );
        crate::kernels::dense::sgemm_wt(&hn, &self.lm_head).data
    }

    /// [`Self::prefill_suffix_with`] generalized to return logits at
    /// **every** suffix position, `[suffix.len(), vocab]` — the
    /// verification forward of speculative decoding. Unlike
    /// `prefill_suffix_with` it takes only the **uncached suffix** (the
    /// caller need not reconstruct the full history; the session's
    /// `pos` rows of cache stand in for it). Row `t` holds the logits
    /// after consuming the cached context plus `suffix[..t + 1]`, so
    /// feeding `[last_emitted, d1..dk]` scores all k drafted tokens
    /// with one batched popcount GEMM per projection: row `t`'s argmax
    /// is exactly what a plain decode step at that position would emit
    /// (token-level identical, test-pinned — the layer loop is shared
    /// code, the only difference is projecting every row of the final
    /// hidden state instead of the last one).
    ///
    /// The session's caches gain one row per suffix token; a verifier
    /// that rejects draft positions rolls them back with
    /// [`DecodeSession::truncate`].
    pub fn prefill_suffix_logits_with(
        &self,
        sess: &mut DecodeSession,
        suffix: &[u16],
        scratch: &mut PrefillScratch,
    ) -> Tensor {
        let t_len = self.prefill_suffix_body(sess, suffix, scratch);
        // logits for every suffix position — scratch.h is free after the
        // layer loop, so norm the whole final hidden state into it and
        // run one [t_len, vocab] GEMM.
        for t in 0..t_len {
            rmsnorm(
                scratch.x.row(t),
                &self.final_norm,
                self.cfg.rmsnorm_eps,
                scratch.h.row_mut(t),
            );
        }
        crate::kernels::dense::sgemm_wt(&scratch.h, &self.lm_head)
    }

    /// Shared layer loop of the warm suffix forwards: embeds the suffix,
    /// runs every block (filling the KV caches), advances `sess.pos`,
    /// and leaves the final hidden states in `scratch.x[..t_len]`.
    /// Returns `t_len` (the suffix length); the callers differ only in
    /// which rows they project to logits.
    fn prefill_suffix_body(
        &self,
        sess: &mut DecodeSession,
        suffix: &[u16],
        scratch: &mut PrefillScratch,
    ) -> usize {
        let m = sess.pos;
        let total = m + suffix.len();
        let d = self.cfg.d_model;
        assert!(total <= self.cfg.max_seq, "sequence longer than max_seq");
        assert!(!suffix.is_empty(), "suffix prefill needs at least one uncached token");
        assert!(
            sess.caches.iter().all(|c| c.len() == m),
            "session caches must cover exactly the reused prefix"
        );
        let t_len = suffix.len();
        scratch.ensure(t_len, d, self.cfg.d_ff);
        let x = &mut scratch.x;
        for t in 0..t_len {
            x.row_mut(t).copy_from_slice(self.embed.row(suffix[t] as usize));
        }
        // Whole-cache K/V dequantization buffers, reused across layers
        // and (via the worker's scratch) across requests.
        scratch.kfull.resize(total * d, 0.0);
        scratch.vfull.resize(total * d, 0.0);
        for (l, blk) in self.blocks.iter().enumerate() {
            // attention — one prepared input feeds wq/wk/wv
            {
                let _p = profile::op_scope(Op::Norm, l, t_len, 0);
                self.norm_all_into(x, &blk.attn_norm, &mut scratch.h);
            }
            {
                let acts = {
                    let _p = profile::op_scope(Op::Pack, l, t_len, 0);
                    blk.attn.wq.exec.prepare(&scratch.h)
                };
                {
                    let _p = profile::op_scope(Op::Wq, l, t_len, blk.attn.wq.exec.plane_bytes());
                    blk.attn.wq.exec.forward_prepared(&acts, &mut scratch.q);
                }
                {
                    let _p = profile::op_scope(Op::Wk, l, t_len, blk.attn.wk.exec.plane_bytes());
                    blk.attn.wk.exec.forward_prepared(&acts, &mut scratch.k);
                }
                {
                    let _p = profile::op_scope(Op::Wv, l, t_len, blk.attn.wv.exec.plane_bytes());
                    blk.attn.wv.exec.forward_prepared(&acts, &mut scratch.v);
                }
            }
            apply_rope(&mut scratch.q, self.cfg.n_heads, self.cfg.rope_theta, m);
            apply_rope(&mut scratch.k, self.cfg.n_heads, self.cfg.rope_theta, m);
            let attn_out = {
                let _p = profile::op_scope(Op::Attn, l, t_len, 0);
                // Push the suffix rows (the cache quantizes on push),
                // then read the *whole* cache back — prefix rows adopted
                // from the pool and suffix rows just written — so suffix
                // attention sees exactly what decode will read.
                let cache = &mut sess.caches[l];
                for t in 0..t_len {
                    cache.k.push(scratch.k.row(t));
                    cache.v.push(scratch.v.row(t));
                }
                debug_assert_eq!(cache.len(), total);
                for t in 0..total {
                    cache.k.get(t, &mut scratch.kfull[t * d..(t + 1) * d]);
                    cache.v.get(t, &mut scratch.vfull[t * d..(t + 1) * d]);
                }
                causal_attention_cached(
                    &scratch.q,
                    &scratch.kfull[..total * d],
                    &scratch.vfull[..total * d],
                    self.cfg.n_heads,
                    m,
                )
            };
            {
                let _p = profile::op_scope(Op::Wo, l, t_len, blk.attn.wo.exec.plane_bytes());
                blk.attn.wo.exec.forward_into(&attn_out, &mut scratch.o);
            }
            for i in 0..x.data.len() {
                x.data[i] += scratch.o.data[i];
            }
            // mlp — gate/up share one prepared input
            {
                let _p = profile::op_scope(Op::Norm, l, t_len, 0);
                self.norm_all_into(x, &blk.mlp_norm, &mut scratch.h);
            }
            {
                let acts = {
                    let _p = profile::op_scope(Op::Pack, l, t_len, 0);
                    blk.mlp.gate.exec.prepare(&scratch.h)
                };
                {
                    let _p = profile::op_scope(Op::Gate, l, t_len, blk.mlp.gate.exec.plane_bytes());
                    blk.mlp.gate.exec.forward_prepared(&acts, &mut scratch.g);
                }
                {
                    let _p = profile::op_scope(Op::Up, l, t_len, blk.mlp.up.exec.plane_bytes());
                    blk.mlp.up.exec.forward_prepared(&acts, &mut scratch.u);
                }
            }
            for i in 0..scratch.g.data.len() {
                scratch.g.data[i] = silu(scratch.g.data[i]) * scratch.u.data[i];
            }
            {
                let _p = profile::op_scope(Op::Down, l, t_len, blk.mlp.down.exec.plane_bytes());
                blk.mlp.down.exec.forward_into(&scratch.g, &mut scratch.dwn);
            }
            for i in 0..x.data.len() {
                x.data[i] += scratch.dwn.data[i];
            }
        }
        sess.pos = total;
        t_len
    }

    /// Feed one token to **each** of `sessions.len()` independent decode
    /// sessions in lockstep and return the `[batch, vocab]` next-position
    /// logits. Per layer the batch is normed into one `[batch, d]` tensor,
    /// activations are quantized + bit-packed **once**, and every
    /// projection runs a single M = batch popcount GEMM
    /// ([`crate::kernels::bwa_gemm::BwaGemm::gemm_packed_into_mt`] when
    /// `threads > 1` and the layer is big enough to amortize a
    /// fork/join) — amortizing the weight-bit traversal across the
    /// whole batch instead of streaming the packed weights once per
    /// sequence. Attention stays per-sequence over each session's INT4
    /// cache; sequences may sit at different positions (RoPE is applied
    /// per row at each session's own `pos`).
    ///
    /// Row `r` is bit-identical to `self.decode_step(&mut sessions[r],
    /// tokens[r])` — the rows of every GEMM, norm, and attention are
    /// computed independently (asserted by parity tests).
    pub fn decode_step_batch(
        &self,
        sessions: &mut [DecodeSession],
        tokens: &[u16],
        threads: usize,
    ) -> Tensor {
        let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
        self.decode_step_batch_refs(&mut refs, tokens, threads)
    }

    /// [`Self::decode_step_batch`] over `&mut` references instead of a
    /// contiguous slice of sessions. The continuous scheduler
    /// ([`crate::coordinator::scheduler`]) keeps each session inside its
    /// slot struct and hands the *ragged active subset* in by reference —
    /// sessions at different positions, admitted at different step
    /// boundaries — without moving sessions in and out of the slots every
    /// step. Row semantics are identical to [`Self::decode_step_batch`]:
    /// row `r` is bit-identical to `decode_step(sessions[r], tokens[r])`.
    pub fn decode_step_batch_refs(
        &self,
        sessions: &mut [&mut DecodeSession],
        tokens: &[u16],
        threads: usize,
    ) -> Tensor {
        let b = sessions.len();
        assert_eq!(tokens.len(), b, "one token per session");
        let d = self.cfg.d_model;
        let d_ff = self.cfg.d_ff;
        let nh = self.cfg.n_heads;
        // Batch buffers are allocated per step: their size follows the
        // shrinking active set, and at `[batch, d]` scale the allocation
        // is noise next to the per-step GEMM/attention work (prefill,
        // the dominant cost, does reuse per-worker scratch).
        let mut x = Tensor::zeros(&[b, d]);
        for (r, &tok) in tokens.iter().enumerate() {
            x.row_mut(r).copy_from_slice(self.embed.row(tok as usize));
        }
        let mut h = Tensor::zeros(&[b, d]);
        let mut q = Tensor::zeros(&[b, d]);
        let mut k = Tensor::zeros(&[b, d]);
        let mut v = Tensor::zeros(&[b, d]);
        let mut attn_out = Tensor::zeros(&[b, d]);
        let mut o = Tensor::zeros(&[b, d]);
        let mut g = Tensor::zeros(&[b, d_ff]);
        let mut u = Tensor::zeros(&[b, d_ff]);
        let mut dwn = Tensor::zeros(&[b, d]);
        let mut scores = Vec::new();
        let mut krow = vec![0.0f32; d];
        let mut vrow = vec![0.0f32; d];
        for (l, blk) in self.blocks.iter().enumerate() {
            {
                let _p = profile::op_scope(Op::Norm, l, b, 0);
                for r in 0..b {
                    rmsnorm(x.row(r), &blk.attn_norm, self.cfg.rmsnorm_eps, h.row_mut(r));
                }
            }
            {
                let acts = {
                    let _p = profile::op_scope(Op::Pack, l, b, 0);
                    blk.attn.wq.exec.prepare(&h)
                };
                {
                    let _p = profile::op_scope(Op::Wq, l, b, blk.attn.wq.exec.plane_bytes());
                    blk.attn.wq.exec.forward_prepared_mt(&acts, &mut q, threads);
                }
                {
                    let _p = profile::op_scope(Op::Wk, l, b, blk.attn.wk.exec.plane_bytes());
                    blk.attn.wk.exec.forward_prepared_mt(&acts, &mut k, threads);
                }
                {
                    let _p = profile::op_scope(Op::Wv, l, b, blk.attn.wv.exec.plane_bytes());
                    blk.attn.wv.exec.forward_prepared_mt(&acts, &mut v, threads);
                }
            }
            for r in 0..b {
                let pos = sessions[r].pos;
                apply_rope_row(q.row_mut(r), nh, self.cfg.rope_theta, pos);
                apply_rope_row(k.row_mut(r), nh, self.cfg.rope_theta, pos);
            }
            {
                let _p = profile::op_scope(Op::Attn, l, b, 0);
                for r in 0..b {
                    let cache = &mut sessions[r].caches[l];
                    cache.k.push(k.row(r));
                    cache.v.push(v.row(r));
                    attend_over_cache(
                        cache,
                        q.row(r),
                        attn_out.row_mut(r),
                        nh,
                        &mut scores,
                        &mut krow,
                        &mut vrow,
                    );
                }
            }
            {
                let _p = profile::op_scope(Op::Wo, l, b, blk.attn.wo.exec.plane_bytes());
                let acts = blk.attn.wo.exec.prepare(&attn_out);
                blk.attn.wo.exec.forward_prepared_mt(&acts, &mut o, threads);
            }
            for i in 0..x.data.len() {
                x.data[i] += o.data[i];
            }
            {
                let _p = profile::op_scope(Op::Norm, l, b, 0);
                for r in 0..b {
                    rmsnorm(x.row(r), &blk.mlp_norm, self.cfg.rmsnorm_eps, h.row_mut(r));
                }
            }
            {
                let acts = {
                    let _p = profile::op_scope(Op::Pack, l, b, 0);
                    blk.mlp.gate.exec.prepare(&h)
                };
                {
                    let _p = profile::op_scope(Op::Gate, l, b, blk.mlp.gate.exec.plane_bytes());
                    blk.mlp.gate.exec.forward_prepared_mt(&acts, &mut g, threads);
                }
                {
                    let _p = profile::op_scope(Op::Up, l, b, blk.mlp.up.exec.plane_bytes());
                    blk.mlp.up.exec.forward_prepared_mt(&acts, &mut u, threads);
                }
            }
            for i in 0..g.data.len() {
                g.data[i] = silu(g.data[i]) * u.data[i];
            }
            {
                let _p = profile::op_scope(Op::Down, l, b, blk.mlp.down.exec.plane_bytes());
                let acts = blk.mlp.down.exec.prepare(&g);
                blk.mlp.down.exec.forward_prepared_mt(&acts, &mut dwn, threads);
            }
            for i in 0..x.data.len() {
                x.data[i] += dwn.data[i];
            }
        }
        for r in 0..b {
            rmsnorm(x.row(r), &self.final_norm, self.cfg.rmsnorm_eps, h.row_mut(r));
        }
        let logits = crate::kernels::dense::sgemm_wt(&h, &self.lm_head);
        for s in sessions.iter_mut() {
            s.pos += 1;
        }
        logits
    }

    /// Total weight storage bytes across quantized linears + FP parts.
    pub fn bytes(&self) -> usize {
        let mut b = (self.embed.numel() + self.lm_head.numel()) * 2; // fp16
        for blk in &self.blocks {
            b += (blk.attn_norm.len() + blk.mlp_norm.len()) * 2;
            b += blk.attn.wq.quant.bytes()
                + blk.attn.wk.quant.bytes()
                + blk.attn.wv.quant.bytes()
                + blk.attn.wo.quant.bytes();
            b += blk.mlp.gate.quant.bytes()
                + blk.mlp.up.quant.bytes()
                + blk.mlp.down.quant.bytes();
        }
        b
    }

    /// Mean weight bits/element over the quantized linears.
    pub fn mean_weight_bits(&self) -> f64 {
        let mut bits = 0.0f64;
        let mut n = 0.0f64;
        for blk in &self.blocks {
            for l in [
                &blk.attn.wq,
                &blk.attn.wk,
                &blk.attn.wv,
                &blk.attn.wo,
                &blk.mlp.gate,
                &blk.mlp.up,
                &blk.mlp.down,
            ] {
                bits += l.quant.weight_bits();
                n += 1.0;
            }
        }
        bits / n.max(1.0)
    }
}

/// Preallocated per-step buffers for incremental decoding — every linear
/// output, norm output, and attention temporary lives here so a decode
/// step performs no per-layer allocation for the compiled-exec path.
struct DecodeScratch {
    /// residual stream `[d]`
    x: Vec<f32>,
    /// RMSNorm output [1, d] (also reused for the final norm)
    h: Tensor,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    attn_out: Tensor,
    o: Tensor,
    g: Tensor,
    u: Tensor,
    dwn: Tensor,
    krow: Vec<f32>,
    vrow: Vec<f32>,
    scores: Vec<f32>,
}

/// Incremental decoding state (position + per-layer INT4 KV caches +
/// scratch buffers).
pub struct DecodeSession {
    pub caches: Vec<LayerKvCache>,
    pub pos: usize,
    /// KV blocks promised to this session at admission
    /// ([`BlockPool::try_reserve`]); `0` for contiguous sessions. On
    /// retirement or preemption the scheduler refunds
    /// `reserved_blocks − Σ caches.blocks_drawn()` — the slice of the
    /// promise the session never allocated (early stop, or a preempt
    /// before the worst case materialized).
    pub reserved_blocks: usize,
    scratch: DecodeScratch,
}

impl DecodeSession {
    /// Roll the session back to `rows` positions, dropping the KV rows
    /// past that point from every layer — speculative-decode rollback of
    /// rejected draft tokens. Paged caches release whole rejected tail
    /// blocks to their pool; the session then continues decoding from
    /// `pos == rows` exactly as if the rejected rows were never fed
    /// (bit-identical, test-pinned).
    pub fn truncate(&mut self, rows: usize) {
        assert!(rows <= self.pos, "truncating past the session position");
        for c in &mut self.caches {
            c.truncate(rows);
        }
        self.pos = rows;
    }

    /// Pool blocks all layers' caches allocated, net of rollbacks — the
    /// consumed part of [`Self::reserved_blocks`].
    pub fn blocks_drawn(&self) -> usize {
        self.caches.iter().map(|c| c.blocks_drawn()).sum()
    }

    /// The unconsumed remainder of this session's admission reservation —
    /// what retirement/preemption refunds via
    /// [`crate::kvpool::BlockPool::unreserve`].
    pub fn unconsumed_reservation(&self) -> usize {
        self.reserved_blocks.saturating_sub(self.blocks_drawn())
    }
}

/// Per-worker scratch for [`Transformer::prefill_with`]: the linear
/// output and norm buffers of one full-sequence forward. A serving
/// worker owns one and reuses it across requests; these buffers are
/// (re)allocated only when the sequence length changes, so a steady
/// stream of same-length prompts reuses them across every request.
#[derive(Default)]
pub struct PrefillScratch {
    x: Tensor,
    h: Tensor,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    o: Tensor,
    g: Tensor,
    u: Tensor,
    dwn: Tensor,
    /// Whole-cache K/V dequantization buffers for the warm suffix path
    /// ([`Transformer::prefill_suffix_with`]); unused by cold prefill.
    kfull: Vec<f32>,
    vfull: Vec<f32>,
}

impl PrefillScratch {
    fn ensure(&mut self, t_len: usize, d: usize, d_ff: usize) {
        fn want(t: &mut Tensor, rows: usize, cols: usize) {
            if t.shape[..] != [rows, cols] {
                *t = Tensor::zeros(&[rows, cols]);
            }
        }
        want(&mut self.x, t_len, d);
        want(&mut self.h, t_len, d);
        want(&mut self.q, t_len, d);
        want(&mut self.k, t_len, d);
        want(&mut self.v, t_len, d);
        want(&mut self.o, t_len, d);
        want(&mut self.g, t_len, d_ff);
        want(&mut self.u, t_len, d_ff);
        want(&mut self.dwn, t_len, d);
    }
}

// ---------------------------------------------------------------------------
// PTQ driver: sequential layer-by-layer quantization with error propagation
// ---------------------------------------------------------------------------

/// Quantize a checkpointed model with any [`Quantizer`], calibrating each
/// linear on the activations produced by the already-quantized prefix of
/// the network (the standard GPTQ/Atom sequential scheme; this is what
/// "utilizing the GPTQ quantization framework" means in the paper's
/// setup). Each layer is identified to the quantizer by a [`LayerCtx`];
/// failures surface as [`ModelError`] instead of panics. Activation
/// propagation runs the compiled execs — the same path serving uses —
/// with one shared preparation for wq/wk/wv and one for gate/up.
pub fn quantize_model(
    ck: &Checkpoint,
    quantizer: &dyn Quantizer,
    calib_seqs: &[Vec<u16>],
    kv_bits: Option<u32>,
) -> Result<Transformer, ModelError> {
    quantize_model_with(ck, quantizer, calib_seqs, kv_bits, 1)
}

/// Parallel [`quantize_model`]: the block-by-block schedule is inherently
/// sequential (each block calibrates on the previous blocks' quantized
/// activations), but *within* a block the projections fed by one tensor
/// (wq/wk/wv; gate/up) and the per-sequence activation propagation are
/// independent — they fan out across up to `threads` workers
/// ([`crate::util::pool::parallel_map`]). Every work item is a pure
/// function of its inputs, so the output is **bit-identical** to the
/// sequential pipeline (test-pinned). This is the engine behind
/// `bwa quantize --jobs`.
pub fn quantize_model_par(
    ck: &Checkpoint,
    quantizer: &dyn Quantizer,
    calib_seqs: &[Vec<u16>],
    kv_bits: Option<u32>,
    threads: usize,
) -> Result<Transformer, ModelError> {
    quantize_model_with(ck, quantizer, calib_seqs, kv_bits, threads.max(1))
}

/// Quantize + compile a group of projections that share one calibration
/// tensor, fanned across `threads` workers. Results (and errors) come
/// back in spec order, so the parallel path reports the same first
/// failure the sequential path would.
fn quantize_group(
    ck: &Checkpoint,
    quantizer: &dyn Quantizer,
    block: usize,
    specs: &[(String, LinearKind)],
    calib: &Tensor,
    threads: usize,
) -> Result<Vec<CompiledLinear>, ModelError> {
    parallel_map(specs.len(), threads, |i| {
        let (name, kind) = &specs[i];
        let ctx = LayerCtx::new(block, name.clone(), *kind);
        ck.get(name)
            .map_err(ModelError::from)
            .and_then(|w| {
                quantizer
                    .quantize_linear(&ctx, w, calib)
                    .map_err(ModelError::from)
            })
            .map(CompiledLinear::new)
    })
    .into_iter()
    .collect()
}

/// Order-preserving parallel map over calibration sequences. Each
/// sequence is processed independently, so the result is element-wise
/// identical to a sequential `map`.
fn map_seqs<F>(xs: &[Tensor], threads: usize, f: F) -> Vec<Tensor>
where
    F: Fn(&Tensor) -> Tensor + Sync,
{
    parallel_map(xs.len(), threads, |i| f(&xs[i]))
}

fn quantize_model_with(
    ck: &Checkpoint,
    quantizer: &dyn Quantizer,
    calib_seqs: &[Vec<u16>],
    kv_bits: Option<u32>,
    threads: usize,
) -> Result<Transformer, ModelError> {
    let cfg = ck.config.clone();
    let d = cfg.d_model;
    let eps = cfg.rmsnorm_eps;

    // Embed all calibration sequences.
    let embed = ck.get("embed")?.clone();
    let mut xs: Vec<Tensor> = calib_seqs
        .iter()
        .map(|seq| {
            let mut x = Tensor::zeros(&[seq.len(), d]);
            for (t, &tok) in seq.iter().enumerate() {
                x.row_mut(t).copy_from_slice(embed.row(tok as usize));
            }
            x
        })
        .collect();

    let norm_seq = |x: &Tensor, gain: &[f32]| -> Tensor {
        let (t_len, _) = x.dims2();
        let mut out = Tensor::zeros(&[t_len, d]);
        for t in 0..t_len {
            rmsnorm(x.row(t), gain, eps, out.row_mut(t));
        }
        out
    };
    let concat = |ts: &[Tensor]| -> Tensor {
        let cols = ts[0].dims2().1;
        let rows: usize = ts.iter().map(|t| t.dims2().0).sum();
        let mut out = Tensor::zeros(&[rows, cols]);
        let mut r = 0;
        for t in ts {
            let (tr, _) = t.dims2();
            out.data[r * cols..(r + tr) * cols].copy_from_slice(&t.data);
            r += tr;
        }
        out
    };

    let mut blocks = Vec::new();
    for l in 0..cfg.n_layers {
        let attn_norm = ck.get(&format!("layers.{l}.attn_norm"))?.data.clone();
        let mlp_norm = ck.get(&format!("layers.{l}.mlp_norm"))?.data.clone();

        // --- attention projections (independent given h_cat: fan out) ---
        let h_seqs = map_seqs(&xs, threads, |x| norm_seq(x, &attn_norm));
        let h_cat = concat(&h_seqs);
        let mut qkv = quantize_group(
            ck,
            quantizer,
            l,
            &[
                (format!("layers.{l}.wq"), LinearKind::Query),
                (format!("layers.{l}.wk"), LinearKind::Key),
                (format!("layers.{l}.wv"), LinearKind::Value),
            ],
            &h_cat,
            threads,
        )?;
        let wv = qkv.pop().expect("wv");
        let wk = qkv.pop().expect("wk");
        let wq = qkv.pop().expect("wq");

        // run attention per sequence with quantized q/k/v (shared prepare)
        let attn_outs = map_seqs(&h_seqs, threads, |h| {
            let (t_len, _) = h.dims2();
            let mut q = Tensor::zeros(&[t_len, d]);
            let mut k = Tensor::zeros(&[t_len, d]);
            let mut v = Tensor::zeros(&[t_len, d]);
            {
                let acts = wq.exec.prepare(h);
                wq.exec.forward_prepared(&acts, &mut q);
                wk.exec.forward_prepared(&acts, &mut k);
                wv.exec.forward_prepared(&acts, &mut v);
            }
            apply_rope(&mut q, cfg.n_heads, cfg.rope_theta, 0);
            apply_rope(&mut k, cfg.n_heads, cfg.rope_theta, 0);
            if kv_bits == Some(4) {
                let (t_len, _) = k.dims2();
                for t in 0..t_len {
                    Kv4Store::fake_quantize(k.row_mut(t));
                    Kv4Store::fake_quantize(v.row_mut(t));
                }
            }
            causal_attention(&q, &k, &v, cfg.n_heads)
        });
        let wo = quantize_group(
            ck,
            quantizer,
            l,
            &[(format!("layers.{l}.wo"), LinearKind::AttnOut)],
            &concat(&attn_outs),
            threads,
        )?
        .pop()
        .expect("wo");
        let os = map_seqs(&attn_outs, threads, |a| wo.forward(a));
        for (x, o) in xs.iter_mut().zip(os.iter()) {
            for i in 0..x.data.len() {
                x.data[i] += o.data[i];
            }
        }

        // --- MLP (gate/up independent given h_cat: fan out) ---
        let h_seqs = map_seqs(&xs, threads, |x| norm_seq(x, &mlp_norm));
        let h_cat = concat(&h_seqs);
        let mut gu = quantize_group(
            ck,
            quantizer,
            l,
            &[
                (format!("layers.{l}.gate"), LinearKind::MlpGate),
                (format!("layers.{l}.up"), LinearKind::MlpUp),
            ],
            &h_cat,
            threads,
        )?;
        let up = gu.pop().expect("up");
        let gate = gu.pop().expect("gate");
        let acts_out = map_seqs(&h_seqs, threads, |h| {
            let (t_len, _) = h.dims2();
            let mut g = Tensor::zeros(&[t_len, cfg.d_ff]);
            let mut u = Tensor::zeros(&[t_len, cfg.d_ff]);
            {
                let acts = gate.exec.prepare(h);
                gate.exec.forward_prepared(&acts, &mut g);
                up.exec.forward_prepared(&acts, &mut u);
            }
            for i in 0..g.data.len() {
                g.data[i] = silu(g.data[i]) * u.data[i];
            }
            g
        });
        let down = quantize_group(
            ck,
            quantizer,
            l,
            &[(format!("layers.{l}.down"), LinearKind::MlpDown)],
            &concat(&acts_out),
            threads,
        )?
        .pop()
        .expect("down");
        let ds = map_seqs(&acts_out, threads, |a| down.forward(a));
        for (x, dwn) in xs.iter_mut().zip(ds.iter()) {
            for i in 0..x.data.len() {
                x.data[i] += dwn.data[i];
            }
        }

        blocks.push(Block {
            attn_norm,
            attn: Attention { wq, wk, wv, wo },
            mlp_norm,
            mlp: Mlp { gate, up, down },
        });
    }

    Ok(Transformer {
        cfg: cfg.clone(),
        embed,
        blocks,
        final_norm: ck.get("final_norm")?.data.clone(),
        lm_head: ck.get("lm_head")?.clone(),
        kv_bits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{BwaQuantizer, FpQuantizer};

    fn small_cfg() -> ModelConfig {
        ModelConfig {
            name: "test".into(),
            vocab_size: 64,
            d_model: 128,
            n_layers: 2,
            n_heads: 2,
            d_ff: 192,
            max_seq: 64,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    #[test]
    fn forward_shape_and_determinism() {
        let model = Transformer::random(&small_cfg(), 1);
        let tokens: Vec<u16> = vec![1, 5, 9, 33, 2];
        let a = model.forward(&tokens);
        let b = model.forward(&tokens);
        assert_eq!(a.dims2(), (5, 64));
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn causality_future_does_not_leak() {
        let model = Transformer::random(&small_cfg(), 2);
        let t1: Vec<u16> = vec![3, 7, 11, 13, 17];
        let t2: Vec<u16> = vec![3, 7, 11, 62, 1]; // differ only at positions 3,4
        let a = model.forward(&t1);
        let b = model.forward(&t2);
        for t in 0..3 {
            crate::util::prop::assert_close(a.row(t), b.row(t), 1e-5, 1e-5)
                .unwrap_or_else(|e| panic!("position {t} leaked: {e}"));
        }
    }

    #[test]
    fn rope_rotation_preserves_norm() {
        let mut rng = Rng::new(3);
        let mut x = Tensor::from_vec(&[4, 128], rng.normal_vec_f32(4 * 128, 0.0, 1.0));
        let before: Vec<f32> = (0..4)
            .map(|t| x.row(t).iter().map(|v| v * v).sum::<f32>())
            .collect();
        apply_rope(&mut x, 2, 10000.0, 0);
        for t in 0..4 {
            let after: f32 = x.row(t).iter().map(|v| v * v).sum();
            assert!((after - before[t]).abs() < 1e-3, "t={t}");
        }
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut rng = Rng::new(4);
        let orig = rng.normal_vec_f32(128, 0.0, 1.0);
        let mut x = Tensor::from_vec(&[1, 128], orig.clone());
        apply_rope(&mut x, 2, 10000.0, 0);
        crate::util::prop::assert_close(&x.data, &orig, 1e-6, 0.0).unwrap();
    }

    #[test]
    fn decode_matches_batch_forward() {
        let mut model = Transformer::random(&small_cfg(), 5);
        model.kv_bits = Some(4); // batch path quantizes K/V like the cache
        let tokens: Vec<u16> = vec![2, 9, 41, 7, 23, 11];
        let batch = model.forward(&tokens);
        let mut sess = model.new_session();
        let mut last = Vec::new();
        for &t in &tokens {
            last = model.decode_step(&mut sess, t);
        }
        let t_last = tokens.len() - 1;
        crate::util::prop::assert_close(&last, batch.row(t_last), 2e-2, 2e-2).unwrap();
    }

    #[test]
    fn fp_quantize_model_matches_checkpoint_forward() {
        let cfg = small_cfg();
        let ck = Checkpoint::random(&cfg, 6);
        let fp = Transformer::fp_from_checkpoint(&ck).unwrap();
        let calib: Vec<Vec<u16>> = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let fp2 = quantize_model(&ck, &FpQuantizer, &calib, None).unwrap();
        let tokens: Vec<u16> = vec![10, 20, 30, 40];
        let a = fp.forward(&tokens);
        let b = fp2.forward(&tokens);
        crate::util::prop::assert_close(&a.data, &b.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn bwa_quantized_model_runs_and_tracks_fp() {
        let cfg = small_cfg();
        let ck = Checkpoint::random(&cfg, 7);
        let fp = Transformer::fp_from_checkpoint(&ck).unwrap();
        let mut rng = Rng::new(8);
        let calib: Vec<Vec<u16>> = (0..4)
            .map(|_| (0..32).map(|_| rng.below(64) as u16).collect())
            .collect();
        let q = quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)).unwrap();
        let tokens: Vec<u16> = (0..16).map(|_| rng.below(64) as u16).collect();
        let a = fp.forward(&tokens);
        let b = q.forward(&tokens);
        // Quantized logits correlate with FP logits (random net: loose).
        let err = crate::util::prop::rel_err(&b.data, &a.data);
        assert!(err < 1.0, "rel err {err}");
        assert!(q.mean_weight_bits() < 8.0);
        assert!(q.bytes() < fp.bytes());
    }

    /// The tentpole parity contract: the compiled popcount path and the
    /// old dense fake-quant path agree, for both prefill and incremental
    /// decode. With no outlier block the two paths compute the same math
    /// and must agree to fp tolerance; the paper config adds the known
    /// sym-vs-asym INT8 outlier-activation delta, so its bound is looser.
    #[test]
    fn compiled_popcount_matches_dense_fake_reference() {
        let cfg = small_cfg();
        let ck = Checkpoint::random(&cfg, 11);
        let mut rng = Rng::new(12);
        let calib: Vec<Vec<u16>> = (0..4)
            .map(|_| (0..32).map(|_| rng.below(64) as u16).collect())
            .collect();
        let tokens: Vec<u16> = (0..12).map(|_| rng.below(64) as u16).collect();

        // exact-math config: binary region only -> fp tolerance
        let q_exact = BwaQuantizer {
            cfg: crate::quant::binarize::BwaConfig {
                outlier_groups: 0,
                ..crate::quant::binarize::BwaConfig::default()
            },
        };
        let m = quantize_model(&ck, &q_exact, &calib, Some(4)).unwrap();
        let packed = m.forward(&tokens);
        let reference = m.forward_reference(&tokens);
        let err = crate::util::prop::rel_err(&packed.data, &reference.data);
        assert!(err < 1e-3, "packed vs fake-quant (no outliers) rel err {err}");

        // paper config: outlier act quant differs sym/asym -> small bound
        let m = quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)).unwrap();
        let packed = m.forward(&tokens);
        let reference = m.forward_reference(&tokens);
        let err = crate::util::prop::rel_err(&packed.data, &reference.data);
        assert!(err < 0.1, "packed vs fake-quant prefill rel err {err}");
        // decode: packed exec through the INT4 cache vs the reference's
        // last position (cache quantization adds its own tolerance)
        let mut sess = m.new_session();
        let mut last = Vec::new();
        for &t in &tokens {
            last = m.decode_step(&mut sess, t);
        }
        let err = crate::util::prop::rel_err(&last, reference.row(tokens.len() - 1));
        assert!(err < 0.15, "packed decode vs fake-quant rel err {err}");
    }

    /// The shared-prepare contract: wq/wk/wv consume one prepared input
    /// (gate/up likewise) and the shared packing equals what each layer
    /// would prepare for itself.
    #[test]
    fn prepared_acts_shared_across_qkv_and_prepared_once() {
        let cfg = small_cfg();
        let ck = Checkpoint::random(&cfg, 13);
        let mut rng = Rng::new(14);
        let calib: Vec<Vec<u16>> = (0..4)
            .map(|_| (0..32).map(|_| rng.below(64) as u16).collect())
            .collect();
        let q = quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)).unwrap();
        let blk = &q.blocks[0];

        // shared packing == per-layer packing, bit for bit
        let x = Tensor::from_vec(
            &[3, cfg.d_model],
            rng.normal_vec_f32(3 * cfg.d_model, 0.0, 1.0),
        );
        let a = blk.attn.wq.exec.prepare(&x);
        let b = blk.attn.wk.exec.prepare(&x);
        let pa = a.packed.as_ref().expect("bwa packs");
        let pb = b.packed.as_ref().expect("bwa packs");
        assert_eq!(pa.sig, pb.sig, "q/k share one packing scheme");
        assert_eq!(pa.acts.planes, pb.acts.planes);
        assert_eq!(pa.acts.mu, pb.acts.mu);
        assert_eq!(pa.acts.shift, pb.acts.shift);
        assert_eq!(pa.acts.r_tot, pb.acts.r_tot);
        assert_eq!(pa.acts.x_out_q, pb.acts.x_out_q);
        assert_eq!(pa.acts.x_out_scale, pb.acts.x_out_scale);

        // one forward prepares once per shared input: wq/wo/gate/down
        // pack, wk/wv/up ride along
        let count = |lin: &CompiledLinear| lin.exec.prepare_invocations();
        let before = [
            count(&blk.attn.wq),
            count(&blk.attn.wk),
            count(&blk.attn.wv),
            count(&blk.attn.wo),
            count(&blk.mlp.gate),
            count(&blk.mlp.up),
            count(&blk.mlp.down),
        ];
        let tokens: Vec<u16> = (0..8).map(|_| rng.below(64) as u16).collect();
        let _ = q.forward(&tokens);
        let blk = &q.blocks[0];
        let after = [
            count(&blk.attn.wq),
            count(&blk.attn.wk),
            count(&blk.attn.wv),
            count(&blk.attn.wo),
            count(&blk.mlp.gate),
            count(&blk.mlp.up),
            count(&blk.mlp.down),
        ];
        let delta: Vec<u64> = after.iter().zip(&before).map(|(a, b)| a - b).collect();
        assert_eq!(delta, vec![1, 0, 0, 1, 1, 0, 1], "prepare-once contract");
    }

    /// The serving-engine prefill contract: one batch forward that fills
    /// the KV cache is interchangeable with a pure decode_step loop.
    #[test]
    fn prefill_matches_decode_step_loop() {
        let model = Transformer::random(&small_cfg(), 17);
        let tokens: Vec<u16> = vec![3, 9, 27, 1, 40, 12, 7, 33];
        // reference: pure incremental decode
        let mut sess_a = model.new_session();
        let mut last_a = Vec::new();
        for &t in &tokens {
            last_a = model.decode_step(&mut sess_a, t);
        }
        // prefill the prompt minus the final token, then decode it
        let mut sess_b = model.new_session_with_capacity(tokens.len());
        let _ = model.prefill(&mut sess_b, &tokens[..tokens.len() - 1]);
        assert_eq!(sess_b.pos, tokens.len() - 1);
        let last_b = model.decode_step(&mut sess_b, tokens[tokens.len() - 1]);
        crate::util::prop::assert_close(&last_b, &last_a, 1e-5, 1e-5).unwrap();
        // prefilling everything yields the same last-position logits
        let mut sess_c = model.new_session();
        let last_c = model.prefill(&mut sess_c, &tokens);
        crate::util::prop::assert_close(&last_c, &last_a, 1e-5, 1e-5).unwrap();
        assert_eq!(sess_c.pos, tokens.len());
    }

    #[test]
    #[should_panic(expected = "fresh session")]
    fn prefill_rejects_used_session() {
        let model = Transformer::random(&small_cfg(), 19);
        let mut sess = model.new_session();
        let _ = model.prefill(&mut sess, &[1, 2, 3]);
        let _ = model.prefill(&mut sess, &[4, 5, 6]);
    }

    /// Lockstep batched decode is row-for-row identical to stepping each
    /// session alone — including sessions at different positions.
    #[test]
    fn decode_step_batch_matches_individual_steps() {
        let model = Transformer::random(&small_cfg(), 18);
        let prompts: Vec<Vec<u16>> = vec![vec![1, 5, 9], vec![7, 2, 60, 33], vec![11]];
        let mut indiv: Vec<DecodeSession> = prompts.iter().map(|_| model.new_session()).collect();
        let mut batch: Vec<DecodeSession> = prompts.iter().map(|_| model.new_session()).collect();
        for (sess, p) in indiv.iter_mut().zip(&prompts) {
            let _ = model.prefill(sess, p);
        }
        for (sess, p) in batch.iter_mut().zip(&prompts) {
            let _ = model.prefill(sess, p);
        }
        for toks in [vec![4u16, 8, 15], vec![9, 3, 22]] {
            let batched = model.decode_step_batch(&mut batch, &toks, 2);
            for (r, (sess, &t)) in indiv.iter_mut().zip(&toks).enumerate() {
                let want = model.decode_step(sess, t);
                crate::util::prop::assert_close(batched.row(r), &want, 1e-6, 1e-6)
                    .unwrap_or_else(|e| panic!("row {r}: {e}"));
            }
        }
        for (a, b) in indiv.iter().zip(&batch) {
            assert_eq!(a.pos, b.pos);
        }
    }

    /// The parallel PTQ pipeline is bit-identical to the sequential one:
    /// same packed bits, same affine params, same dequantized weights,
    /// same logits — parallelism only reorders independent work items.
    #[test]
    fn quantize_model_par_matches_sequential_bitwise() {
        let cfg = small_cfg();
        let ck = Checkpoint::random(&cfg, 21);
        let mut rng = Rng::new(22);
        let calib: Vec<Vec<u16>> = (0..4)
            .map(|_| (0..32).map(|_| rng.below(64) as u16).collect())
            .collect();
        let seq = quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)).unwrap();
        let par = quantize_model_par(&ck, &BwaQuantizer::paper(), &calib, Some(4), 4).unwrap();
        for (a, b) in seq.blocks.iter().zip(par.blocks.iter()) {
            for (la, lb) in [
                (&a.attn.wq, &b.attn.wq),
                (&a.attn.wk, &b.attn.wk),
                (&a.attn.wv, &b.attn.wv),
                (&a.attn.wo, &b.attn.wo),
                (&a.mlp.gate, &b.mlp.gate),
                (&a.mlp.up, &b.mlp.up),
                (&a.mlp.down, &b.mlp.down),
            ] {
                let qa = la
                    .quant
                    .as_any()
                    .downcast_ref::<crate::quant::binarize::BwaLinear>()
                    .unwrap();
                let qb = lb
                    .quant
                    .as_any()
                    .downcast_ref::<crate::quant::binarize::BwaLinear>()
                    .unwrap();
                assert_eq!(qa.perm, qb.perm);
                assert_eq!(qa.qbits.words, qb.qbits.words);
                assert_eq!(qa.mbits.words, qb.mbits.words);
                assert_eq!(qa.alpha, qb.alpha);
                assert_eq!(qa.beta, qb.beta);
                assert_eq!(qa.w_hat.data, qb.w_hat.data);
            }
        }
        let tokens: Vec<u16> = (0..12).map(|_| rng.below(64) as u16).collect();
        assert_eq!(seq.forward(&tokens).data, par.forward(&tokens).data);
    }

    #[test]
    fn quantize_model_surfaces_layer_errors() {
        // d_model = 96 is not a multiple of the 64-channel group size, so
        // the paper's method must refuse the first projection — as an
        // error naming the layer, not a panic.
        let cfg = ModelConfig {
            name: "bad".into(),
            vocab_size: 32,
            d_model: 96,
            n_layers: 1,
            n_heads: 2,
            d_ff: 96,
            max_seq: 32,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        };
        let ck = Checkpoint::random(&cfg, 15);
        let calib: Vec<Vec<u16>> = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        match quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)) {
            Err(ModelError::Quant(q)) => {
                assert!(q.to_string().contains("layers.0.wq"), "{q}");
            }
            Err(other) => panic!("expected quant error, got {other}"),
            Ok(_) => panic!("expected quantization to fail"),
        }
    }

    /// The paged-KV parity contract, part 1: a paged session is
    /// bit-identical to a contiguous one through prefill + decode_step
    /// and through lockstep decode_step_batch — with a block size that
    /// divides neither the prompt length nor the total, so rows straddle
    /// block boundaries on every path.
    #[test]
    fn paged_sessions_match_contiguous_on_decode_paths() {
        use crate::kvpool::KvPoolConfig;
        let cfg = small_cfg();
        let ck = Checkpoint::random(&cfg, 23);
        let mut rng = Rng::new(24);
        let calib: Vec<Vec<u16>> = (0..4)
            .map(|_| (0..32).map(|_| rng.below(64) as u16).collect())
            .collect();
        let model = quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)).unwrap();
        let pool = Arc::new(BlockPool::new(KvPoolConfig {
            blocks: 256,
            block_tokens: 5,
        }));

        // prefill + decode_step
        let prompt: Vec<u16> = (0..12).map(|_| rng.below(64) as u16).collect();
        let mut flat = model.new_session_with_capacity(prompt.len() + 4);
        let mut paged = model.new_session_paged(&pool);
        let a = model.prefill(&mut flat, &prompt);
        let b = model.prefill(&mut paged, &prompt);
        assert_eq!(a, b, "prefill logits must be bit-identical across backings");
        for &t in &[7u16, 21, 3, 40] {
            let a = model.decode_step(&mut flat, t);
            let b = model.decode_step(&mut paged, t);
            assert_eq!(a, b, "decode_step diverged between backings");
        }

        // lockstep decode_step_batch over paged sessions
        let prompts: Vec<Vec<u16>> = vec![vec![1, 5, 9], vec![7, 2, 60, 33, 8, 11, 2], vec![11]];
        let mut indiv: Vec<DecodeSession> =
            prompts.iter().map(|_| model.new_session()).collect();
        let mut batch: Vec<DecodeSession> =
            prompts.iter().map(|_| model.new_session_paged(&pool)).collect();
        for (sess, p) in indiv.iter_mut().zip(&prompts) {
            let _ = model.prefill(sess, p);
        }
        for (sess, p) in batch.iter_mut().zip(&prompts) {
            let _ = model.prefill(sess, p);
        }
        for toks in [vec![4u16, 8, 15], vec![9, 3, 22]] {
            let batched = model.decode_step_batch(&mut batch, &toks, 2);
            for (r, (sess, &t)) in indiv.iter_mut().zip(&toks).enumerate() {
                let want = model.decode_step(sess, t);
                assert_eq!(batched.row(r), &want[..], "batched row {r} diverged");
            }
        }
        drop(flat);
        drop(paged);
        drop(batch);
        assert_eq!(pool.in_use(), 0, "retired paged sessions must release every block");
    }

    /// The paged-KV parity contract, part 2: warm suffix prefill — cold
    /// (`pos == 0`) it is bit-identical to `prefill`, and a session that
    /// adopts a cached prefix through the `PrefixIndex` produces the
    /// same logits as a cold full prefill, then stays bit-identical
    /// through subsequent decode steps.
    #[test]
    fn suffix_prefill_and_prefix_reuse_match_cold_prefill() {
        use crate::kvpool::{KvPoolConfig, PrefixIndex};
        let cfg = small_cfg();
        let model = Transformer::random(&cfg, 29);
        let pool = Arc::new(BlockPool::new(KvPoolConfig {
            blocks: 256,
            block_tokens: 5,
        }));
        let mut index = PrefixIndex::new(5, cfg.n_layers);
        let prompt: Vec<u16> = vec![3, 9, 27, 1, 40, 12, 7, 33, 20, 2, 14, 6];
        let mut scratch = PrefillScratch::default();

        // cold references: contiguous prefill and paged suffix-from-zero
        let mut cold = model.new_session();
        let want = model.prefill(&mut cold, &prompt);
        let mut paged = model.new_session_paged(&pool);
        let got = model.prefill_suffix_with(&mut paged, &prompt, &mut scratch);
        assert_eq!(got, want, "suffix prefill from pos 0 must equal cold prefill");

        // publish the prompt, then serve it again through the index
        let per_layer: Vec<_> = paged
            .caches
            .iter_mut()
            .map(|c| c.freeze_prefix(prompt.len()).expect("paged cache"))
            .collect();
        index.insert(&prompt, &per_layer, &pool);
        let m = index.lookup(&prompt, &pool);
        assert_eq!(m.rows, 11, "2 full 5-row blocks + 1 shared tail row");
        let mut warm = model.new_session_from_prefix(&pool, m);
        let got = model.prefill_suffix_with(&mut warm, &prompt, &mut scratch);
        assert_eq!(got, want, "prefix-reusing prefill must equal cold prefill");

        // and decode stays bit-identical after the reuse (the first push
        // copy-on-writes the shared tail block)
        for &t in &[5u16, 18, 2, 61] {
            let a = model.decode_step(&mut cold, t);
            let b = model.decode_step(&mut warm, t);
            assert_eq!(a, b, "decode after prefix reuse diverged");
        }

        drop(paged);
        drop(warm);
        index.clear(&pool);
        assert_eq!(pool.in_use(), 0, "index clear + session drop releases everything");
    }

    /// The speculative-verification contract, part 1: the multi-position
    /// suffix forward returns one logits row per suffix token, row `t`
    /// agreeing with a plain decode step at the same position (same
    /// greedy token; the last row is bit-identical to
    /// `prefill_suffix_with`, which shares the layer loop).
    #[test]
    fn suffix_logits_rows_track_decode_steps() {
        fn argmax(l: &[f32]) -> usize {
            let mut best = 0;
            for i in 1..l.len() {
                if l[i] > l[best] {
                    best = i;
                }
            }
            best
        }
        let model = Transformer::random(&small_cfg(), 31);
        let prompt: Vec<u16> = vec![3, 9, 27, 1, 40, 12, 7, 33];
        let cont: Vec<u16> = vec![5, 18, 2, 61];
        let mut scratch = PrefillScratch::default();

        // reference: plain incremental decode of the continuation
        let mut ref_sess = model.new_session();
        let _ = model.prefill(&mut ref_sess, &prompt);
        let ref_logits: Vec<Vec<f32>> =
            cont.iter().map(|&t| model.decode_step(&mut ref_sess, t)).collect();

        // verify forward: all continuation rows in one suffix pass
        let mut spec_sess = model.new_session();
        let _ = model.prefill(&mut spec_sess, &prompt);
        let rows = model.prefill_suffix_logits_with(&mut spec_sess, &cont, &mut scratch);
        assert_eq!(rows.shape, vec![cont.len(), small_cfg().vocab_size]);
        assert_eq!(spec_sess.pos, prompt.len() + cont.len());
        for (t, want) in ref_logits.iter().enumerate() {
            crate::util::prop::assert_close(rows.row(t), want, 1e-3, 1e-3)
                .unwrap_or_else(|e| panic!("suffix row {t} vs decode step: {e}"));
            assert_eq!(
                argmax(rows.row(t)),
                argmax(want),
                "greedy token diverged at suffix row {t}"
            );
        }

        // last row == the single-logit suffix forward, bit for bit
        let mut all: Vec<u16> = prompt.clone();
        all.extend_from_slice(&cont);
        let mut single = model.new_session();
        let _ = model.prefill(&mut single, &prompt);
        let last = model.prefill_suffix_with(&mut single, &all, &mut scratch);
        assert_eq!(rows.row(cont.len() - 1), &last[..], "last-row projection");
    }

    /// The speculative-verification contract, part 2: rolling rejected
    /// draft rows back with [`DecodeSession::truncate`] leaves the
    /// session decoding exactly like one that never saw them — for both
    /// cache backings, with the paged pool's block accounting restored.
    #[test]
    fn truncate_rolls_back_speculative_rows() {
        use crate::kvpool::KvPoolConfig;
        let model = Transformer::random(&small_cfg(), 37);
        let pool = Arc::new(BlockPool::new(KvPoolConfig {
            blocks: 256,
            block_tokens: 5,
        }));
        let prompt: Vec<u16> = vec![4, 19, 2, 57, 8, 30, 12];
        let mut scratch = PrefillScratch::default();
        for paged in [false, true] {
            let mk = || {
                if paged {
                    model.new_session_paged(&pool)
                } else {
                    model.new_session()
                }
            };
            // reference: accept one continuation token, then decode on
            let mut ref_sess = mk();
            let _ = model.prefill(&mut ref_sess, &prompt);
            let _ = model.decode_step(&mut ref_sess, 21);
            let ref_next = model.decode_step(&mut ref_sess, 44);

            // speculative: feed [21, 9, 50] as a suffix, reject the last
            // two draft rows, then decode the same token
            let mut spec = mk();
            let _ = model.prefill(&mut spec, &prompt);
            let _ = model.prefill_suffix_logits_with(&mut spec, &[21, 9, 50], &mut scratch);
            spec.truncate(prompt.len() + 1);
            assert_eq!(spec.pos, ref_sess.pos - 1);
            for c in &spec.caches {
                assert_eq!(c.len(), prompt.len() + 1);
            }
            let spec_next = model.decode_step(&mut spec, 44);
            crate::util::prop::assert_close(&spec_next, &ref_next, 1e-3, 1e-3)
                .unwrap_or_else(|e| panic!("post-rollback decode (paged={paged}): {e}"));
        }
        assert_eq!(pool.in_use(), 0, "rollback + drop must release every block");
    }

    #[test]
    fn checkpoint_roundtrip_through_disk() {
        let cfg = small_cfg();
        let ck = Checkpoint::random(&cfg, 9);
        let dir = std::env::temp_dir().join("bwa_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let a = Transformer::fp_from_checkpoint(&ck).unwrap();
        let b = Transformer::fp_from_checkpoint(&back).unwrap();
        let tokens: Vec<u16> = vec![5, 6, 7];
        assert_eq!(a.forward(&tokens).data, b.forward(&tokens).data);
        std::fs::remove_file(&path).ok();
    }
}
