//! LLaMA-like transformer inference stack with pluggable quantized
//! linears.
//!
//! Architecture (matching the paper's LLAMA target and Figure 2's BWA
//! attention): token embedding → N × [RMSNorm → MHA(RoPE, INT4 KV) →
//! residual → RMSNorm → SwiGLU MLP → residual] → RMSNorm → LM head.
//!
//! Every projection (`wq wk wv wo gate up down`) is a `Box<dyn
//! QuantLinear>`, so the same model code runs FP16, the paper's
//! W(1+1)A(1×4), and every baseline — the evaluation harness swaps the
//! quantizer, nothing else. Embedding and LM head stay FP (standard PTQ
//! practice, also what the baselines in the paper do).

pub mod checkpoint;
pub mod config;
pub mod kv_cache;

use crate::model::checkpoint::Checkpoint;
use crate::model::config::ModelConfig;
use crate::model::kv_cache::{Kv4Store, LayerKvCache};
use crate::quant::{QuantLinear, Quantizer};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::softmax_inplace;

/// RMSNorm with learned gain.
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f64, out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / (ms + eps).sqrt() as f32;
    for i in 0..x.len() {
        out[i] = x[i] * inv * gain[i];
    }
}

/// Rotary position embedding applied in place to one [T, d] tensor with
/// `n_heads` heads (pairs rotated within each head).
pub fn apply_rope(x: &mut Tensor, n_heads: usize, theta: f64, pos_offset: usize) {
    let (t_len, d) = x.dims2();
    let hd = d / n_heads;
    for t in 0..t_len {
        let pos = (t + pos_offset) as f64;
        let row = x.row_mut(t);
        for h in 0..n_heads {
            let base = h * hd;
            for i in 0..hd / 2 {
                let freq = 1.0 / theta.powf(2.0 * i as f64 / hd as f64);
                let angle = pos * freq;
                let (sin, cos) = (angle.sin() as f32, angle.cos() as f32);
                let a = row[base + 2 * i];
                let b = row[base + 2 * i + 1];
                row[base + 2 * i] = a * cos - b * sin;
                row[base + 2 * i + 1] = a * sin + b * cos;
            }
        }
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Multi-head attention block.
pub struct Attention {
    pub wq: Box<dyn QuantLinear>,
    pub wk: Box<dyn QuantLinear>,
    pub wv: Box<dyn QuantLinear>,
    pub wo: Box<dyn QuantLinear>,
}

/// SwiGLU MLP block.
pub struct Mlp {
    pub gate: Box<dyn QuantLinear>,
    pub up: Box<dyn QuantLinear>,
    pub down: Box<dyn QuantLinear>,
}

pub struct Block {
    pub attn_norm: Vec<f32>,
    pub attn: Attention,
    pub mlp_norm: Vec<f32>,
    pub mlp: Mlp,
}

pub struct Transformer {
    pub cfg: ModelConfig,
    pub embed: Tensor,
    pub blocks: Vec<Block>,
    pub final_norm: Vec<f32>,
    pub lm_head: Tensor,
    /// KV quantization bits (None = FP cache; Some(4) for quantized runs).
    pub kv_bits: Option<u32>,
}

/// Core of causal batch attention given q/k/v [T, d]: per-head causal
/// softmax(q·kᵀ/√hd)·v. K/V are already (fake-)quantized by the caller.
pub fn causal_attention(q: &Tensor, k: &Tensor, v: &Tensor, n_heads: usize) -> Tensor {
    let (t_len, d) = q.dims2();
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Tensor::zeros(&[t_len, d]);
    let mut scores = vec![0.0f32; t_len];
    for h in 0..n_heads {
        let base = h * hd;
        for tq in 0..t_len {
            let qrow = &q.row(tq)[base..base + hd];
            for tk in 0..=tq {
                let krow = &k.row(tk)[base..base + hd];
                let mut s = 0.0f32;
                for i in 0..hd {
                    s += qrow[i] * krow[i];
                }
                scores[tk] = s * scale;
            }
            softmax_inplace(&mut scores[..=tq]);
            let orow = &mut out.row_mut(tq)[base..base + hd];
            for tk in 0..=tq {
                let w = scores[tk];
                let vrow = &v.row(tk)[base..base + hd];
                for i in 0..hd {
                    orow[i] += w * vrow[i];
                }
            }
        }
    }
    out
}

impl Transformer {
    /// Random FP model (tests and micro-benches).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Transformer {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let std = 0.08;
        let lin = |rng: &mut Rng, o: usize, i: usize| -> Box<dyn QuantLinear> {
            Box::new(crate::quant::FpLinear {
                w: Tensor::from_vec(&[o, i], rng.normal_vec_f32(o * i, 0.0, std)),
            })
        };
        let blocks = (0..cfg.n_layers)
            .map(|_| Block {
                attn_norm: vec![1.0; d],
                attn: Attention {
                    wq: lin(&mut rng, d, d),
                    wk: lin(&mut rng, d, d),
                    wv: lin(&mut rng, d, d),
                    wo: lin(&mut rng, d, d),
                },
                mlp_norm: vec![1.0; d],
                mlp: Mlp {
                    gate: lin(&mut rng, cfg.d_ff, d),
                    up: lin(&mut rng, cfg.d_ff, d),
                    down: lin(&mut rng, d, cfg.d_ff),
                },
            })
            .collect();
        Transformer {
            cfg: cfg.clone(),
            embed: Tensor::from_vec(
                &[cfg.vocab_size, d],
                rng.normal_vec_f32(cfg.vocab_size * d, 0.0, 0.5),
            ),
            blocks,
            final_norm: vec![1.0; d],
            lm_head: Tensor::from_vec(
                &[cfg.vocab_size, d],
                rng.normal_vec_f32(cfg.vocab_size * d, 0.0, std),
            ),
            kv_bits: None,
        }
    }

    /// FP model from a trainer checkpoint.
    pub fn fp_from_checkpoint(ck: &Checkpoint) -> Result<Transformer, checkpoint::CkptError> {
        let cfg = ck.config.clone();
        let lin = |name: &str| -> Result<Box<dyn QuantLinear>, checkpoint::CkptError> {
            Ok(Box::new(crate::quant::FpLinear {
                w: ck.get(name)?.clone(),
            }))
        };
        let mut blocks = Vec::new();
        for l in 0..cfg.n_layers {
            blocks.push(Block {
                attn_norm: ck.get(&format!("layers.{l}.attn_norm"))?.data.clone(),
                attn: Attention {
                    wq: lin(&format!("layers.{l}.wq"))?,
                    wk: lin(&format!("layers.{l}.wk"))?,
                    wv: lin(&format!("layers.{l}.wv"))?,
                    wo: lin(&format!("layers.{l}.wo"))?,
                },
                mlp_norm: ck.get(&format!("layers.{l}.mlp_norm"))?.data.clone(),
                mlp: Mlp {
                    gate: lin(&format!("layers.{l}.gate"))?,
                    up: lin(&format!("layers.{l}.up"))?,
                    down: lin(&format!("layers.{l}.down"))?,
                },
            });
        }
        Ok(Transformer {
            cfg: cfg.clone(),
            embed: ck.get("embed")?.clone(),
            blocks,
            final_norm: ck.get("final_norm")?.data.clone(),
            lm_head: ck.get("lm_head")?.clone(),
            kv_bits: None,
        })
    }

    fn norm_all(&self, x: &Tensor, gain: &[f32]) -> Tensor {
        let (t_len, d) = x.dims2();
        let mut out = Tensor::zeros(&[t_len, d]);
        for t in 0..t_len {
            rmsnorm(x.row(t), gain, self.cfg.rmsnorm_eps, out.row_mut(t));
        }
        out
    }

    fn maybe_kv_quant(&self, x: &mut Tensor) {
        if let Some(bits) = self.kv_bits {
            debug_assert_eq!(bits, 4, "only INT4 KV supported");
            let (t_len, _) = x.dims2();
            for t in 0..t_len {
                Kv4Store::fake_quantize(x.row_mut(t));
            }
        }
    }

    /// Batch forward: logits [T, vocab] for a token sequence (causal).
    pub fn forward(&self, tokens: &[u16]) -> Tensor {
        let t_len = tokens.len();
        let d = self.cfg.d_model;
        assert!(t_len <= self.cfg.max_seq, "sequence longer than max_seq");
        let mut x = Tensor::zeros(&[t_len, d]);
        for (t, &tok) in tokens.iter().enumerate() {
            x.row_mut(t).copy_from_slice(self.embed.row(tok as usize));
        }
        for blk in &self.blocks {
            // attention
            let h = self.norm_all(&x, &blk.attn_norm);
            let mut q = blk.attn.wq.forward(&h);
            let mut k = blk.attn.wk.forward(&h);
            let mut v = blk.attn.wv.forward(&h);
            apply_rope(&mut q, self.cfg.n_heads, self.cfg.rope_theta, 0);
            apply_rope(&mut k, self.cfg.n_heads, self.cfg.rope_theta, 0);
            self.maybe_kv_quant(&mut k);
            self.maybe_kv_quant(&mut v);
            let attn_out = causal_attention(&q, &k, &v, self.cfg.n_heads);
            let o = blk.attn.wo.forward(&attn_out);
            for i in 0..x.data.len() {
                x.data[i] += o.data[i];
            }
            // mlp
            let h = self.norm_all(&x, &blk.mlp_norm);
            let g = blk.mlp.gate.forward(&h);
            let u = blk.mlp.up.forward(&h);
            let mut act = Tensor::zeros(&[t_len, self.cfg.d_ff]);
            for i in 0..act.data.len() {
                act.data[i] = silu(g.data[i]) * u.data[i];
            }
            let dwn = blk.mlp.down.forward(&act);
            for i in 0..x.data.len() {
                x.data[i] += dwn.data[i];
            }
        }
        let xn = self.norm_all(&x, &self.final_norm);
        crate::kernels::dense::sgemm_wt(&xn, &self.lm_head)
    }

    /// Start an incremental decoding session (per-layer INT4 KV caches).
    pub fn new_session(&self) -> DecodeSession {
        DecodeSession {
            caches: (0..self.cfg.n_layers)
                .map(|_| LayerKvCache::new(self.cfg.d_model))
                .collect(),
            pos: 0,
        }
    }

    /// Feed one token; returns logits [vocab] for the next position.
    /// Uses the INT4 KV cache — the serving path. For FP models the cache
    /// still quantizes to INT4 when `kv_bits` is set, else stores FP
    /// equivalents via 16-bit-exact round trip (here: quantized always, to
    /// keep one cache implementation; FP-cache equivalence is covered by
    /// `kv_bits: Some(4)` tests).
    pub fn decode_step(&self, sess: &mut DecodeSession, token: u16) -> Vec<f32> {
        let d = self.cfg.d_model;
        let hd = self.cfg.head_dim();
        let nh = self.cfg.n_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut x = self.embed.row(token as usize).to_vec();

        for (l, blk) in self.blocks.iter().enumerate() {
            let mut h = vec![0.0f32; d];
            rmsnorm(&x, &blk.attn_norm, self.cfg.rmsnorm_eps, &mut h);
            let ht = Tensor::from_vec(&[1, d], h);
            let mut q = blk.attn.wq.forward(&ht);
            let mut k = blk.attn.wk.forward(&ht);
            let v = blk.attn.wv.forward(&ht);
            apply_rope(&mut q, nh, self.cfg.rope_theta, sess.pos);
            apply_rope(&mut k, nh, self.cfg.rope_theta, sess.pos);
            let cache = &mut sess.caches[l];
            cache.k.push(k.row(0));
            cache.v.push(v.row(0));
            let t_len = cache.len();
            // per-head attention over the quantized cache
            let mut attn_out = vec![0.0f32; d];
            let mut krow = vec![0.0f32; d];
            let mut scores = vec![0.0f32; t_len];
            for hh in 0..nh {
                let base = hh * hd;
                let qh = &q.row(0)[base..base + hd];
                for t in 0..t_len {
                    cache.k.get(t, &mut krow);
                    let mut s = 0.0f32;
                    for i in 0..hd {
                        s += qh[i] * krow[base + i];
                    }
                    scores[t] = s * scale;
                }
                softmax_inplace(&mut scores);
                let mut vrow = vec![0.0f32; d];
                for t in 0..t_len {
                    cache.v.get(t, &mut vrow);
                    let w = scores[t];
                    for i in 0..hd {
                        attn_out[base + i] += w * vrow[base + i];
                    }
                }
            }
            let o = blk
                .attn
                .wo
                .forward(&Tensor::from_vec(&[1, d], attn_out));
            for i in 0..d {
                x[i] += o.data[i];
            }
            // mlp
            let mut h = vec![0.0f32; d];
            rmsnorm(&x, &blk.mlp_norm, self.cfg.rmsnorm_eps, &mut h);
            let ht = Tensor::from_vec(&[1, d], h);
            let g = blk.mlp.gate.forward(&ht);
            let u = blk.mlp.up.forward(&ht);
            let mut act = Tensor::zeros(&[1, self.cfg.d_ff]);
            for i in 0..self.cfg.d_ff {
                act.data[i] = silu(g.data[i]) * u.data[i];
            }
            let dwn = blk.mlp.down.forward(&act);
            for i in 0..d {
                x[i] += dwn.data[i];
            }
        }
        sess.pos += 1;
        let mut xn = vec![0.0f32; d];
        rmsnorm(&x, &self.final_norm, self.cfg.rmsnorm_eps, &mut xn);
        let logits = crate::kernels::dense::sgemm_wt(
            &Tensor::from_vec(&[1, d], xn),
            &self.lm_head,
        );
        logits.data
    }

    /// Total weight storage bytes across quantized linears + FP parts.
    pub fn bytes(&self) -> usize {
        let mut b = (self.embed.numel() + self.lm_head.numel()) * 2; // fp16
        for blk in &self.blocks {
            b += (blk.attn_norm.len() + blk.mlp_norm.len()) * 2;
            b += blk.attn.wq.bytes()
                + blk.attn.wk.bytes()
                + blk.attn.wv.bytes()
                + blk.attn.wo.bytes();
            b += blk.mlp.gate.bytes() + blk.mlp.up.bytes() + blk.mlp.down.bytes();
        }
        b
    }

    /// Mean weight bits/element over the quantized linears.
    pub fn mean_weight_bits(&self) -> f64 {
        let mut bits = 0.0f64;
        let mut n = 0.0f64;
        for blk in &self.blocks {
            for l in [
                &blk.attn.wq,
                &blk.attn.wk,
                &blk.attn.wv,
                &blk.attn.wo,
                &blk.mlp.gate,
                &blk.mlp.up,
                &blk.mlp.down,
            ] {
                bits += l.weight_bits();
                n += 1.0;
            }
        }
        bits / n.max(1.0)
    }
}

/// Incremental decoding state (position + per-layer INT4 KV caches).
pub struct DecodeSession {
    pub caches: Vec<LayerKvCache>,
    pub pos: usize,
}

// ---------------------------------------------------------------------------
// PTQ driver: sequential layer-by-layer quantization with error propagation
// ---------------------------------------------------------------------------

/// Quantize a checkpointed model with any [`Quantizer`], calibrating each
/// linear on the activations produced by the already-quantized prefix of
/// the network (the standard GPTQ/Atom sequential scheme; this is what
/// "utilizing the GPTQ quantization framework" means in the paper's setup).
pub fn quantize_model(
    ck: &Checkpoint,
    quantizer: &dyn Quantizer,
    calib_seqs: &[Vec<u16>],
    kv_bits: Option<u32>,
) -> Result<Transformer, checkpoint::CkptError> {
    let cfg = ck.config.clone();
    let d = cfg.d_model;
    let eps = cfg.rmsnorm_eps;

    // Embed all calibration sequences.
    let embed = ck.get("embed")?.clone();
    let mut xs: Vec<Tensor> = calib_seqs
        .iter()
        .map(|seq| {
            let mut x = Tensor::zeros(&[seq.len(), d]);
            for (t, &tok) in seq.iter().enumerate() {
                x.row_mut(t).copy_from_slice(embed.row(tok as usize));
            }
            x
        })
        .collect();

    let norm_seq = |x: &Tensor, gain: &[f32]| -> Tensor {
        let (t_len, _) = x.dims2();
        let mut out = Tensor::zeros(&[t_len, d]);
        for t in 0..t_len {
            rmsnorm(x.row(t), gain, eps, out.row_mut(t));
        }
        out
    };
    let concat = |ts: &[Tensor]| -> Tensor {
        let cols = ts[0].dims2().1;
        let rows: usize = ts.iter().map(|t| t.dims2().0).sum();
        let mut out = Tensor::zeros(&[rows, cols]);
        let mut r = 0;
        for t in ts {
            let (tr, _) = t.dims2();
            out.data[r * cols..(r + tr) * cols].copy_from_slice(&t.data);
            r += tr;
        }
        out
    };

    let mut blocks = Vec::new();
    for l in 0..cfg.n_layers {
        let attn_norm = ck.get(&format!("layers.{l}.attn_norm"))?.data.clone();
        let mlp_norm = ck.get(&format!("layers.{l}.mlp_norm"))?.data.clone();

        // --- attention projections ---
        let h_seqs: Vec<Tensor> = xs.iter().map(|x| norm_seq(x, &attn_norm)).collect();
        let h_cat = concat(&h_seqs);
        let wq = quantizer.quantize_linear(ck.get(&format!("layers.{l}.wq"))?, &h_cat);
        let wk = quantizer.quantize_linear(ck.get(&format!("layers.{l}.wk"))?, &h_cat);
        let wv = quantizer.quantize_linear(ck.get(&format!("layers.{l}.wv"))?, &h_cat);

        // run attention per sequence with quantized q/k/v
        let mut attn_outs = Vec::new();
        for h in &h_seqs {
            let mut q = wq.forward(h);
            let mut k = wk.forward(h);
            let v = wv.forward(h);
            apply_rope(&mut q, cfg.n_heads, cfg.rope_theta, 0);
            apply_rope(&mut k, cfg.n_heads, cfg.rope_theta, 0);
            let mut k = k;
            let mut v = v;
            if kv_bits == Some(4) {
                let (t_len, _) = k.dims2();
                for t in 0..t_len {
                    Kv4Store::fake_quantize(k.row_mut(t));
                    Kv4Store::fake_quantize(v.row_mut(t));
                }
            }
            attn_outs.push(causal_attention(&q, &k, &v, cfg.n_heads));
        }
        let wo = quantizer.quantize_linear(
            ck.get(&format!("layers.{l}.wo"))?,
            &concat(&attn_outs),
        );
        for (x, a) in xs.iter_mut().zip(attn_outs.iter()) {
            let o = wo.forward(a);
            for i in 0..x.data.len() {
                x.data[i] += o.data[i];
            }
        }

        // --- MLP ---
        let h_seqs: Vec<Tensor> = xs.iter().map(|x| norm_seq(x, &mlp_norm)).collect();
        let h_cat = concat(&h_seqs);
        let gate = quantizer.quantize_linear(ck.get(&format!("layers.{l}.gate"))?, &h_cat);
        let up = quantizer.quantize_linear(ck.get(&format!("layers.{l}.up"))?, &h_cat);
        let mut acts = Vec::new();
        for h in &h_seqs {
            let g = gate.forward(h);
            let u = up.forward(h);
            let mut act = Tensor::zeros(&g.shape.clone());
            for i in 0..act.data.len() {
                act.data[i] = silu(g.data[i]) * u.data[i];
            }
            acts.push(act);
        }
        let down = quantizer.quantize_linear(
            ck.get(&format!("layers.{l}.down"))?,
            &concat(&acts),
        );
        for (x, a) in xs.iter_mut().zip(acts.iter()) {
            let dwn = down.forward(a);
            for i in 0..x.data.len() {
                x.data[i] += dwn.data[i];
            }
        }

        blocks.push(Block {
            attn_norm,
            attn: Attention { wq, wk, wv, wo },
            mlp_norm,
            mlp: Mlp { gate, up, down },
        });
    }

    Ok(Transformer {
        cfg: cfg.clone(),
        embed,
        blocks,
        final_norm: ck.get("final_norm")?.data.clone(),
        lm_head: ck.get("lm_head")?.clone(),
        kv_bits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{BwaQuantizer, FpQuantizer};
    use std::collections::BTreeMap;

    fn small_cfg() -> ModelConfig {
        ModelConfig {
            name: "test".into(),
            vocab_size: 64,
            d_model: 128,
            n_layers: 2,
            n_heads: 2,
            d_ff: 192,
            max_seq: 64,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    fn random_checkpoint(cfg: &ModelConfig, seed: u64) -> Checkpoint {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let mut tensors = BTreeMap::new();
        fn add(
            tensors: &mut BTreeMap<String, Tensor>,
            name: String,
            shape: &[usize],
            rng: &mut Rng,
            std: f32,
        ) {
            let n: usize = shape.iter().product();
            tensors.insert(name, Tensor::from_vec(shape, rng.normal_vec_f32(n, 0.0, std)));
        }
        add(&mut tensors, "embed".into(), &[cfg.vocab_size, d], &mut rng, 0.5);
        add(&mut tensors, "lm_head".into(), &[cfg.vocab_size, d], &mut rng, 0.08);
        for l in 0..cfg.n_layers {
            add(&mut tensors, format!("layers.{l}.wq"), &[d, d], &mut rng, 0.08);
            add(&mut tensors, format!("layers.{l}.wk"), &[d, d], &mut rng, 0.08);
            add(&mut tensors, format!("layers.{l}.wv"), &[d, d], &mut rng, 0.08);
            add(&mut tensors, format!("layers.{l}.wo"), &[d, d], &mut rng, 0.08);
            add(&mut tensors, format!("layers.{l}.gate"), &[cfg.d_ff, d], &mut rng, 0.08);
            add(&mut tensors, format!("layers.{l}.up"), &[cfg.d_ff, d], &mut rng, 0.08);
            add(&mut tensors, format!("layers.{l}.down"), &[d, cfg.d_ff], &mut rng, 0.08);
            tensors.insert(
                format!("layers.{l}.attn_norm"),
                Tensor::from_vec(&[d], vec![1.0; d]),
            );
            tensors.insert(
                format!("layers.{l}.mlp_norm"),
                Tensor::from_vec(&[d], vec![1.0; d]),
            );
        }
        tensors.insert("final_norm".into(), Tensor::from_vec(&[d], vec![1.0; d]));
        Checkpoint {
            config: cfg.clone(),
            tensors,
        }
    }

    #[test]
    fn forward_shape_and_determinism() {
        let model = Transformer::random(&small_cfg(), 1);
        let tokens: Vec<u16> = vec![1, 5, 9, 33, 2];
        let a = model.forward(&tokens);
        let b = model.forward(&tokens);
        assert_eq!(a.dims2(), (5, 64));
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn causality_future_does_not_leak() {
        let model = Transformer::random(&small_cfg(), 2);
        let t1: Vec<u16> = vec![3, 7, 11, 13, 17];
        let t2: Vec<u16> = vec![3, 7, 11, 62, 1]; // differ only at positions 3,4
        let a = model.forward(&t1);
        let b = model.forward(&t2);
        for t in 0..3 {
            crate::util::prop::assert_close(a.row(t), b.row(t), 1e-5, 1e-5)
                .unwrap_or_else(|e| panic!("position {t} leaked: {e}"));
        }
    }

    #[test]
    fn rope_rotation_preserves_norm() {
        let mut rng = Rng::new(3);
        let mut x = Tensor::from_vec(&[4, 128], rng.normal_vec_f32(4 * 128, 0.0, 1.0));
        let before: Vec<f32> = (0..4)
            .map(|t| x.row(t).iter().map(|v| v * v).sum::<f32>())
            .collect();
        apply_rope(&mut x, 2, 10000.0, 0);
        for t in 0..4 {
            let after: f32 = x.row(t).iter().map(|v| v * v).sum();
            assert!((after - before[t]).abs() < 1e-3, "t={t}");
        }
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut rng = Rng::new(4);
        let orig = rng.normal_vec_f32(128, 0.0, 1.0);
        let mut x = Tensor::from_vec(&[1, 128], orig.clone());
        apply_rope(&mut x, 2, 10000.0, 0);
        crate::util::prop::assert_close(&x.data, &orig, 1e-6, 0.0).unwrap();
    }

    #[test]
    fn decode_matches_batch_forward() {
        let mut model = Transformer::random(&small_cfg(), 5);
        model.kv_bits = Some(4); // batch path quantizes K/V like the cache
        let tokens: Vec<u16> = vec![2, 9, 41, 7, 23, 11];
        let batch = model.forward(&tokens);
        let mut sess = model.new_session();
        let mut last = Vec::new();
        for &t in &tokens {
            last = model.decode_step(&mut sess, t);
        }
        let t_last = tokens.len() - 1;
        crate::util::prop::assert_close(&last, batch.row(t_last), 2e-2, 2e-2).unwrap();
    }

    #[test]
    fn fp_quantize_model_matches_checkpoint_forward() {
        let cfg = small_cfg();
        let ck = random_checkpoint(&cfg, 6);
        let fp = Transformer::fp_from_checkpoint(&ck).unwrap();
        let calib: Vec<Vec<u16>> = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let fp2 = quantize_model(&ck, &FpQuantizer, &calib, None).unwrap();
        let tokens: Vec<u16> = vec![10, 20, 30, 40];
        let a = fp.forward(&tokens);
        let b = fp2.forward(&tokens);
        crate::util::prop::assert_close(&a.data, &b.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn bwa_quantized_model_runs_and_tracks_fp() {
        let cfg = small_cfg();
        let ck = random_checkpoint(&cfg, 7);
        let fp = Transformer::fp_from_checkpoint(&ck).unwrap();
        let mut rng = Rng::new(8);
        let calib: Vec<Vec<u16>> = (0..4)
            .map(|_| (0..32).map(|_| rng.below(64) as u16).collect())
            .collect();
        let q = quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)).unwrap();
        let tokens: Vec<u16> = (0..16).map(|_| rng.below(64) as u16).collect();
        let a = fp.forward(&tokens);
        let b = q.forward(&tokens);
        // Quantized logits correlate with FP logits (random net: loose).
        let err = crate::util::prop::rel_err(&b.data, &a.data);
        assert!(err < 1.0, "rel err {err}");
        assert!(q.mean_weight_bits() < 8.0);
        assert!(q.bytes() < fp.bytes());
    }

    #[test]
    fn checkpoint_roundtrip_through_disk() {
        let cfg = small_cfg();
        let ck = random_checkpoint(&cfg, 9);
        let dir = std::env::temp_dir().join("bwa_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let a = Transformer::fp_from_checkpoint(&ck).unwrap();
        let b = Transformer::fp_from_checkpoint(&back).unwrap();
        let tokens: Vec<u16> = vec![5, 6, 7];
        assert_eq!(a.forward(&tokens).data, b.forward(&tokens).data);
        std::fs::remove_file(&path).ok();
    }
}
