//! Model configuration — LLaMA-style hyperparameters, JSON-serializable
//! so the Python trainer and the Rust runtime agree on one source of truth.

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub rmsnorm_eps: f64,
}

impl ModelConfig {
    /// The "7B-analog" tiny model (see DESIGN.md §2 for scaling).
    /// d_model and d_ff are multiples of the 64-channel group size.
    pub fn tiny() -> Self {
        Self {
            name: "tiny".into(),
            vocab_size: 512,
            d_model: 192,
            n_layers: 3,
            n_heads: 3,
            d_ff: 512,
            max_seq: 160,
            rope_theta: 10000.0,
            rmsnorm_eps: 1e-5,
        }
    }

    /// The "13B-analog": wider + deeper.
    pub fn tiny_13b() -> Self {
        Self {
            name: "tiny-13b".into(),
            d_model: 256,
            n_layers: 4,
            n_heads: 4,
            d_ff: 640,
            ..Self::tiny()
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count (embeddings + blocks + head).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let attn = 4 * d * d;
        let mlp = 3 * d * self.d_ff;
        let norms = 2 * d;
        self.vocab_size * d // embed
            + self.n_layers * (attn + mlp + norms)
            + d // final norm
            + self.vocab_size * d // lm head
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("vocab_size", Json::num(self.vocab_size as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("d_ff", Json::num(self.d_ff as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
            ("rope_theta", Json::num(self.rope_theta)),
            ("rmsnorm_eps", Json::num(self.rmsnorm_eps)),
        ])
    }

    pub fn from_json(j: &Json) -> ModelConfig {
        ModelConfig {
            name: j.str_or("name", "tiny").to_string(),
            vocab_size: j.usize_or("vocab_size", 512),
            d_model: j.usize_or("d_model", 256),
            n_layers: j.usize_or("n_layers", 4),
            n_heads: j.usize_or("n_heads", 4),
            d_ff: j.usize_or("d_ff", 640),
            max_seq: j.usize_or("max_seq", 256),
            rope_theta: j.f64_or("rope_theta", 10000.0),
            rmsnorm_eps: j.f64_or("rmsnorm_eps", 1e-5),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::tiny_13b();
        let j = c.to_json();
        let back = ModelConfig::from_json(&Json::parse(&j.to_string()).unwrap());
        assert_eq!(c, back);
    }

    #[test]
    fn head_dim_divides() {
        let c = ModelConfig::tiny();
        assert_eq!(c.head_dim() * c.n_heads, c.d_model);
        let c13 = ModelConfig::tiny_13b();
        assert_eq!(c13.head_dim() * c13.n_heads, c13.d_model);
    }

    #[test]
    fn param_count_sane() {
        let c = ModelConfig::tiny();
        let p = c.param_count();
        // embed 512*192≈98k ×2 + 3 layers × (147k attn + 295k mlp) ≈ 1.5M
        assert!(p > 1_000_000 && p < 3_000_000, "params {p}");
    }
}
