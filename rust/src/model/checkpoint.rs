//! Checkpoint I/O — the interchange format between the JAX trainer
//! (`python/compile/train.py`) and the Rust runtime.
//!
//! Layout (little endian):
//! ```text
//! magic   8 bytes  "BWACKPT1"
//! hdr_len u32      JSON header byte length
//! header  JSON     {"config": {...}, "tensors": [{"name","shape","offset"}]}
//! data    f32[]    tensor payloads, contiguous, in header order
//! ```
//! Offsets are element offsets into the f32 payload region.

use crate::model::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"BWACKPT1";

#[derive(Debug)]
pub struct Checkpoint {
    pub config: ModelConfig,
    pub tensors: BTreeMap<String, Tensor>,
}

#[derive(Debug)]
pub struct CkptError(pub String);

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "checkpoint: {}", self.0)
    }
}

impl std::error::Error for CkptError {}

fn err(msg: impl Into<String>) -> CkptError {
    CkptError(msg.into())
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<(), CkptError> {
        let mut entries = Vec::new();
        let mut offset = 0usize;
        for (name, t) in &self.tensors {
            entries.push(Json::obj(vec![
                ("name", Json::str(name.clone())),
                (
                    "shape",
                    Json::Arr(t.shape.iter().map(|&d| Json::num(d as f64)).collect()),
                ),
                ("offset", Json::num(offset as f64)),
            ]));
            offset += t.numel();
        }
        let header = Json::obj(vec![
            ("config", self.config.to_json()),
            ("tensors", Json::Arr(entries)),
        ])
        .to_string();

        // Buffered writer: the per-tensor write_all calls below would
        // otherwise each hit the file directly.
        let mut f = BufWriter::new(std::fs::File::create(path).map_err(|e| err(e.to_string()))?);
        f.write_all(MAGIC).map_err(|e| err(e.to_string()))?;
        f.write_all(&(header.len() as u32).to_le_bytes())
            .map_err(|e| err(e.to_string()))?;
        f.write_all(header.as_bytes()).map_err(|e| err(e.to_string()))?;
        for (_, t) in &self.tensors {
            let bytes: Vec<u8> = t.data.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes).map_err(|e| err(e.to_string()))?;
        }
        f.flush().map_err(|e| err(e.to_string()))
    }

    pub fn load(path: &Path) -> Result<Checkpoint, CkptError> {
        let file = std::fs::File::open(path)
            .map_err(|e| err(format!("open {}: {e}", path.display())))?;
        let mut f = BufReader::new(file);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic).map_err(|e| err(e.to_string()))?;
        if &magic != MAGIC {
            return Err(err("bad magic (not a BWACKPT1 checkpoint)"));
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4).map_err(|e| err(e.to_string()))?;
        let hdr_len = u32::from_le_bytes(len4) as usize;
        let mut hdr = vec![0u8; hdr_len];
        f.read_exact(&mut hdr).map_err(|e| err(e.to_string()))?;
        let header = Json::parse(
            std::str::from_utf8(&hdr).map_err(|_| err("header not utf8"))?,
        )
        .map_err(|e| err(format!("header json: {e}")))?;

        let config = ModelConfig::from_json(header.get("config"));
        let mut payload = Vec::new();
        f.read_to_end(&mut payload).map_err(|e| err(e.to_string()))?;
        if payload.len() % 4 != 0 {
            return Err(err("payload not a multiple of 4 bytes"));
        }
        let floats: Vec<f32> = payload
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();

        let mut tensors = BTreeMap::new();
        for e in header
            .get("tensors")
            .as_arr()
            .ok_or_else(|| err("missing tensors"))?
        {
            let name = e.str_or("name", "").to_string();
            let shape: Vec<usize> = e
                .get("shape")
                .as_arr()
                .ok_or_else(|| err("missing shape"))?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let offset = e.usize_or("offset", 0);
            let n: usize = shape.iter().product();
            if offset + n > floats.len() {
                return Err(err(format!("tensor {name} out of bounds")));
            }
            tensors.insert(
                name,
                Tensor::from_vec(&shape, floats[offset..offset + n].to_vec()),
            );
        }
        Ok(Checkpoint { config, tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor, CkptError> {
        self.tensors
            .get(name)
            .ok_or_else(|| err(format!("missing tensor '{name}'")))
    }

    /// Random checkpoint with the full tensor layout of a trained model —
    /// for tests and benches that need a quantizable model without
    /// `make artifacts`.
    pub fn random(cfg: &ModelConfig, seed: u64) -> Checkpoint {
        let mut rng = crate::util::rng::Rng::new(seed);
        let d = cfg.d_model;
        let mut tensors = BTreeMap::new();
        let mut add = |name: String, shape: &[usize], rng: &mut crate::util::rng::Rng, std: f32| {
            let n: usize = shape.iter().product();
            tensors.insert(name, Tensor::from_vec(shape, rng.normal_vec_f32(n, 0.0, std)));
        };
        add("embed".into(), &[cfg.vocab_size, d], &mut rng, 0.5);
        add("lm_head".into(), &[cfg.vocab_size, d], &mut rng, 0.08);
        for l in 0..cfg.n_layers {
            add(format!("layers.{l}.wq"), &[d, d], &mut rng, 0.08);
            add(format!("layers.{l}.wk"), &[d, d], &mut rng, 0.08);
            add(format!("layers.{l}.wv"), &[d, d], &mut rng, 0.08);
            add(format!("layers.{l}.wo"), &[d, d], &mut rng, 0.08);
            add(format!("layers.{l}.gate"), &[cfg.d_ff, d], &mut rng, 0.08);
            add(format!("layers.{l}.up"), &[cfg.d_ff, d], &mut rng, 0.08);
            add(format!("layers.{l}.down"), &[d, cfg.d_ff], &mut rng, 0.08);
            add(format!("layers.{l}.attn_norm"), &[d], &mut rng, 0.0);
            add(format!("layers.{l}.mlp_norm"), &[d], &mut rng, 0.0);
        }
        add("final_norm".into(), &[d], &mut rng, 0.0);
        // norms get unit gain, not noise
        for (name, t) in tensors.iter_mut() {
            if name.ends_with("norm") {
                t.data.iter_mut().for_each(|v| *v = 1.0);
            }
        }
        Checkpoint {
            config: cfg.clone(),
            tensors,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(1);
        let dir = std::env::temp_dir().join("bwa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.bin");
        let mut tensors = BTreeMap::new();
        tensors.insert(
            "embed".to_string(),
            Tensor::from_vec(&[8, 4], rng.normal_vec_f32(32, 0.0, 1.0)),
        );
        tensors.insert(
            "layer0.wq".to_string(),
            Tensor::from_vec(&[4, 4], rng.normal_vec_f32(16, 0.0, 1.0)),
        );
        let ck = Checkpoint {
            config: ModelConfig::tiny(),
            tensors,
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.config, ck.config);
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.get("embed").unwrap().data, ck.get("embed").unwrap().data);
        assert_eq!(
            back.get("layer0.wq").unwrap().shape,
            ck.get("layer0.wq").unwrap().shape
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("bwa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTACKPTxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let ck = Checkpoint {
            config: ModelConfig::tiny(),
            tensors: BTreeMap::new(),
        };
        assert!(ck.get("nope").is_err());
    }
}

#[cfg(test)]
mod failure_tests {
    use super::*;

    #[test]
    fn truncated_payload_is_rejected() {
        let dir = std::env::temp_dir().join("bwa_ckpt_fail");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.bin");
        // valid magic + header pointing past the payload
        let header = r#"{"config":{},"tensors":[{"name":"w","shape":[4,4],"offset":0}]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&[0u8; 8]); // only 2 floats, need 16
        std::fs::write(&path, bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_header_is_rejected() {
        let dir = std::env::temp_dir().join("bwa_ckpt_fail");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hdr.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(5u32).to_le_bytes());
        bytes.extend_from_slice(b"{nope");
        std::fs::write(&path, bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
