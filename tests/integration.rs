//! Cross-module and cross-layer integration tests.
//!
//! Tests that need trained checkpoints / AOT artifacts skip gracefully
//! when `make artifacts` has not run (CI bootstrap order).

use bwa_llm::baselines;
use bwa_llm::data::corpus::CorpusSpec;
use bwa_llm::eval::{evaluate, EvalBudget};
use bwa_llm::kernels::bwa_gemm::BwaGemm;
use bwa_llm::model::checkpoint::Checkpoint;
use bwa_llm::model::{quantize_model, Transformer};
use bwa_llm::quant::{BwaQuantizer, FpQuantizer};
use bwa_llm::util::prop::rel_err;
use std::path::Path;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("models/llama1-7b.bin").exists() {
        Some(p)
    } else {
        None
    }
}

fn calib() -> Vec<Vec<u16>> {
    let train = bwa_llm::data::corpus::train_split(&CorpusSpec::wiki(), 100_000);
    bwa_llm::data::calibration_windows(&train, 8, 96, 17)
}

#[test]
fn trained_model_beats_chance_and_quantized_tracks_fp() {
    let Some(dir) = artifacts() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let ck = Checkpoint::load(&dir.join("models/llama1-7b.bin")).unwrap();
    let budget = EvalBudget {
        ppl_tokens: 512,
        seq_len: 96,
        zs_items: 16,
        mmlu_items: 8,
    };
    let fp = quantize_model(&ck, &FpQuantizer, &calib(), None).unwrap();
    let r_fp = evaluate(&fp, "fp", &budget, 3);
    // the trained model must have learned the fact structure
    assert!(r_fp.ppl[0].1 < 60.0, "wiki ppl {}", r_fp.ppl[0].1);
    assert!(r_fp.zs_avg > 0.55, "zs avg {}", r_fp.zs_avg);

    let q = quantize_model(&ck, &BwaQuantizer::paper(), &calib(), Some(4)).unwrap();
    let r_q = evaluate(&q, "bwa", &budget, 3);
    // W(1+1)A(1x4) stays close to FP (the paper's headline)
    assert!(
        r_q.ppl[0].1 < r_fp.ppl[0].1 * 1.6,
        "bwa ppl {} vs fp {}",
        r_q.ppl[0].1,
        r_fp.ppl[0].1
    );
    assert!(r_q.zs_avg > r_fp.zs_avg - 0.15);
}

#[test]
fn bwa_beats_w2a4_baselines_on_trained_model() {
    let Some(dir) = artifacts() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let ck = Checkpoint::load(&dir.join("models/llama1-7b.bin")).unwrap();
    let budget = EvalBudget {
        ppl_tokens: 512,
        seq_len: 96,
        zs_items: 8,
        mmlu_items: 8,
    };
    let ours = quantize_model(&ck, &BwaQuantizer::paper(), &calib(), Some(4)).unwrap();
    let p_ours = evaluate(&ours, "ours", &budget, 3).ppl[0].1;

    let gptq1 = baselines::by_name("gptq-w1a4").unwrap();
    let g = quantize_model(&ck, gptq1.as_ref(), &calib(), Some(4)).unwrap();
    let p_gptq1 = evaluate(&g, "gptq-w1a4", &budget, 3).ppl[0].1;

    // W1A4 GPTQ collapses relative to ours (Figure 1 / Table 5 shape)
    assert!(
        p_gptq1 > 2.0 * p_ours,
        "gptq-w1a4 {p_gptq1} should collapse vs ours {p_ours}"
    );
}

/// One test covers both PJRT artifacts. The PJRT CPU plugin does not
/// survive a client destroy/recreate cycle within one process (buffer
/// bookkeeping aborts on the second client), so the transformer and
/// kernel sessions are created in one test with overlapping lifetimes —
/// the same discipline the serving coordinator follows (one client per
/// process, built on the batcher thread).
#[test]
fn pjrt_artifacts_match_native_and_kernel_contract() {
    let Some(dir) = artifacts() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    if !dir.join("transformer_fp.hlo.txt").exists() || !dir.join("bwa_linear.hlo.txt").exists()
    {
        eprintln!("skipped: no AOT artifacts");
        return;
    }
    // --- transformer artifact vs native forward ---
    let ck = Checkpoint::load(&dir.join("models/llama1-7b.bin")).unwrap();
    let native = Transformer::fp_from_checkpoint(&ck).unwrap();
    let session = bwa_llm::runtime::TransformerSession::load(dir, &ck).unwrap();

    let tokens: Vec<u16> = bwa_llm::data::corpus::train_split(&CorpusSpec::wiki(), 200)
        [..session.seq]
        .to_vec();
    let pjrt_logits = session.forward(&tokens).unwrap();
    let native_logits = native.forward(&tokens);
    let err = rel_err(&pjrt_logits, &native_logits.data);
    // Same checkpoint, two independent implementations (JAX->HLO->PJRT vs
    // pure Rust): logits must agree tightly.
    assert!(err < 5e-3, "pjrt vs native rel err {err}");

    // --- Pallas kernel artifact (keep the transformer session alive) ---
    let kernel = bwa_llm::runtime::KernelSession::load(dir).unwrap();
    run_kernel_contract(&kernel);
    drop(session);
}

fn run_kernel_contract(session: &bwa_llm::runtime::KernelSession) {
    let m = &session.manifest;
    let t = m.usize_or("tokens", 4);
    let o = m.usize_or("out_features", 192);
    let n = m.usize_or("in_features", 192);
    let g = m.usize_or("group_size", 64);
    let ng = n / g;

    // all-zero bit planes + unit scales -> y = shift*wsum exactly
    let shift_val = 0.25f32;
    let wsum_val = 2.0f32;
    let inputs: Vec<(Vec<usize>, Vec<f32>)> = vec![
        (vec![t, 4, n], vec![0.0; t * 4 * n]),
        (vec![t, 4], vec![1.0; t * 4]),
        (vec![t], vec![shift_val; t]),
        (vec![o, n], vec![0.0; o * n]),
        (vec![o, n], vec![0.0; o * n]),
        (vec![o, ng, 2], vec![0.1; o * ng * 2]),
        (vec![o, ng, 2], vec![0.0; o * ng * 2]),
        (vec![o], vec![wsum_val; o]),
    ];
    let y = session.run(&inputs).unwrap();
    assert_eq!(y.len(), t * o);
    for &v in &y {
        assert!((v - shift_val * wsum_val).abs() < 1e-5, "{v}");
    }
}

#[test]
fn binary_gemm_matches_fake_path_on_quantized_checkpoint_layer() {
    let Some(dir) = artifacts() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let ck = Checkpoint::load(&dir.join("models/llama1-7b.bin")).unwrap();
    let w = ck.get("layers.0.wq").unwrap();
    let mut x = bwa_llm::tensor::Tensor::zeros(&[64, w.dims2().1]);
    let mut rng = bwa_llm::util::rng::Rng::new(5);
    for v in &mut x.data {
        *v = rng.normal_f32(0.0, 1.0);
    }
    let lin = bwa_llm::quant::binarize::quantize_bwa(
        w,
        &x,
        &bwa_llm::quant::binarize::BwaConfig::paper(),
    );
    let xt = bwa_llm::tensor::Tensor::from_vec(
        &[3, w.dims2().1],
        rng.normal_vec_f32(3 * w.dims2().1, 0.0, 1.0),
    );
    let fake = lin.forward(&xt);
    let bits = BwaGemm::prepare(&lin).forward(&xt);
    let err = rel_err(&bits.data, &fake.data);
    assert!(err < 0.02, "bit path err {err}");
}

#[test]
fn serve_coordinator_over_quantized_model() {
    use bwa_llm::coordinator::batcher::{Backend, BatcherConfig};
    use bwa_llm::coordinator::{serve_workload, NativeBackend};
    let Some(dir) = artifacts() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let ck = Checkpoint::load(&dir.join("models/llama1-7b.bin")).unwrap();
    let report = serve_workload(
        move || {
            let model = quantize_model(&ck, &BwaQuantizer::paper(), &calib(), Some(4)).unwrap();
            Box::new(NativeBackend {
                model,
                label: "it-bwa".into(),
            }) as Box<dyn Backend>
        },
        16,
        2,
        12,
        1,
        BatcherConfig::default(),
        9,
    );
    assert!(report.contains("requests:    16"), "{report}");
}

/// The parallel batched engine through the full coordinator stack
/// (clients → batcher → engine), on a quantized random checkpoint so it
/// runs without `make artifacts`: every request is served, multi-token
/// generation is accounted, and batching actually happens.
#[test]
fn serve_coordinator_parallel_engine_end_to_end() {
    use bwa_llm::coordinator::batcher::{Backend, BatcherConfig};
    use bwa_llm::coordinator::{serve_workload_stats, ParallelBackend};
    use bwa_llm::model::config::ModelConfig;
    use std::time::Duration;

    let cfg = ModelConfig {
        name: "it-engine".into(),
        vocab_size: 512,
        d_model: 128,
        n_layers: 2,
        n_heads: 2,
        d_ff: 192,
        max_seq: 64,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    };
    let ck = Checkpoint::random(&cfg, 23);
    let calib: Vec<Vec<u16>> = (0..4u16)
        .map(|s| (0..32u16).map(|t| (s * 37 + t * 11) % 512).collect())
        .collect();
    let (name, stats, _wall) = serve_workload_stats(
        move || {
            let model = quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)).unwrap();
            Box::new(ParallelBackend::new(model, 2, "it-bwa-par")) as Box<dyn Backend>
        },
        12,
        3,
        10,
        3,
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
        13,
    );
    assert!(name.contains("parallel"), "{name}");
    assert_eq!(stats.requests, 12);
    assert_eq!(stats.gen_tokens, 12 * 3, "every request generates gen tokens");
    assert!(stats.mean_batch >= 1.0);
    assert_eq!(stats.latency.len(), 12);
}

/// Quantize once, serve many — through the whole stack and the disk:
/// a model is quantized (in parallel), compiled to a `.bwa` artifact,
/// reloaded with no checkpoint or calibration data in sight, and the
/// engine serves the *same greedy tokens* from the loaded artifact as
/// from the original in-memory model.
#[test]
fn artifact_roundtrip_serves_identical_tokens() {
    use bwa_llm::coordinator::batcher::Backend;
    use bwa_llm::coordinator::ParallelBackend;
    use bwa_llm::model::config::ModelConfig;
    use bwa_llm::model::quantize_model_par;

    let cfg = ModelConfig {
        name: "it-artifact".into(),
        vocab_size: 512,
        d_model: 128,
        n_layers: 2,
        n_heads: 2,
        d_ff: 192,
        max_seq: 64,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    };
    let ck = Checkpoint::random(&cfg, 29);
    let calib: Vec<Vec<u16>> = (0..4u16)
        .map(|s| (0..32u16).map(|t| (s * 37 + t * 11) % 512).collect())
        .collect();
    let model = quantize_model_par(&ck, &BwaQuantizer::paper(), &calib, Some(4), 2).unwrap();

    let dir = std::env::temp_dir().join("bwa_it_artifact");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("it.bwa");
    bwa_llm::artifact::save(&model, "bwa", &path).unwrap();
    let loaded = bwa_llm::artifact::load(&path).unwrap();
    assert_eq!(loaded.meta.method, "bwa");

    let prompts: Vec<Vec<u16>> = (0..3u16)
        .map(|s| (0..10u16).map(|t| (s * 101 + t * 13) % 512).collect())
        .collect();
    let seq_refs: Vec<&[u16]> = prompts.iter().map(|p| p.as_slice()).collect();
    let gens = [4usize, 3, 4];
    let from_memory = ParallelBackend::new(model, 2, "mem");
    let from_disk = ParallelBackend::new(loaded.model, 2, "disk");
    assert_eq!(
        from_memory.generate_batch(&seq_refs, &gens),
        from_disk.generate_batch(&seq_refs, &gens),
        "artifact-loaded model diverged from the quantized model"
    );
    std::fs::remove_file(&path).ok();
}

/// Batcher drain policy under a pre-queued burst: exactly `n` requests
/// served in ceil(n / max_batch) batches with the correct mean batch
/// size — nothing dropped, nothing served twice.
#[test]
fn batcher_drains_burst_in_full_batches() {
    use bwa_llm::coordinator::batcher::{run_batcher, Backend, BatcherConfig, Request};
    use bwa_llm::coordinator::scheduler::Priority;
    use bwa_llm::model::sampling::GenConfig;
    use std::sync::mpsc;
    use std::time::{Duration, Instant};

    struct CountBackend;
    impl Backend for CountBackend {
        fn name(&self) -> String {
            "count".into()
        }
        fn last_logits_batch(&self, seqs: &[&[u16]]) -> Vec<Vec<f32>> {
            seqs.iter().map(|_| vec![1.0f32, 0.0]).collect()
        }
    }

    let (tx, rx) = mpsc::channel::<Request>();
    let (rtx, rrx) = mpsc::channel();
    for id in 0..16u64 {
        tx.send(Request {
            id,
            tokens: vec![1, 2],
            gen: 2,
            submitted: Instant::now(),
            resp_tx: rtx.clone(),
            stream_tx: None,
            cfg: GenConfig::default(),
            priority: Priority::default(),
            trace: None,
        })
        .unwrap();
    }
    drop(tx);
    drop(rtx);
    let stats = run_batcher(
        rx,
        &CountBackend,
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        },
    );
    let mut served = 0;
    while let Ok(resp) = rrx.recv() {
        assert_eq!(resp.generated.len(), 2);
        assert_eq!(resp.generated[0], resp.next_token);
        served += 1;
    }
    assert_eq!(served, 16);
    assert_eq!(stats.requests, 16);
    assert_eq!(stats.batches, 2, "16 pre-queued requests at max_batch 8");
    assert!((stats.mean_batch - 8.0).abs() < 1e-9, "{}", stats.mean_batch);
    assert_eq!(stats.gen_tokens, 32);
}

/// The continuous-batching scheduler through the whole stack (staggered
/// clients → mpsc → run_scheduler → TransformerBackend) on a quantized
/// random checkpoint: every request is served, every token is accounted
/// at token granularity (one TTFT sample per request, gen-1 ITL samples
/// per request), and occupancy respects the slot-pool bound. (Whether
/// requests *overlap* here depends on host timing; deterministic
/// overlap/admission pins live in `coordinator/scheduler.rs` tests.)
#[test]
fn continuous_scheduler_serves_staggered_arrivals_end_to_end() {
    use bwa_llm::coordinator::scheduler::{SchedPolicy, SchedulerConfig, TransformerBackend};
    use bwa_llm::coordinator::{serve_continuous_load, Workload};
    use bwa_llm::model::config::ModelConfig;
    use std::time::Duration;

    let cfg = ModelConfig {
        name: "it-cont".into(),
        vocab_size: 512,
        d_model: 128,
        n_layers: 2,
        n_heads: 2,
        d_ff: 192,
        max_seq: 64,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    };
    let ck = Checkpoint::random(&cfg, 37);
    let calib: Vec<Vec<u16>> = (0..4u16)
        .map(|s| (0..32u16).map(|t| (s * 37 + t * 11) % 512).collect())
        .collect();
    let load = Workload {
        requests: 12,
        clients: 3,
        prompt_len: 10,
        gen: 3,
        shared_prefix: 0,
        stagger: Duration::from_micros(500),
        seed: 13,
        long_requests: 0,
        long_prompt_len: 0,
    };
    let (name, stats, _wall) = serve_continuous_load(
        move || {
            let model = quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)).unwrap();
            TransformerBackend::new(model, 2, "it-bwa-cont")
        },
        &load,
        SchedulerConfig {
            max_active: 4,
            policy: SchedPolicy::eager(),
            spec_k: 0,
        },
    );
    assert!(name.contains("continuous"), "{name}");
    assert_eq!(stats.requests, 12);
    assert_eq!(stats.gen_tokens, 12 * 3, "every request generates gen tokens");
    assert_eq!(stats.ttft.len(), 12, "one TTFT sample per request");
    assert_eq!(stats.itl.len(), 12 * 2, "gen - 1 ITL samples per request");
    assert_eq!(stats.latency.len(), 12);
    assert!(
        (1.0..=4.0).contains(&stats.mean_active),
        "occupancy must stay within the slot-pool bound, got {}",
        stats.mean_active
    );
    assert!(stats.steps >= 2, "multi-token decode must take batched steps");
}

/// The paged KV pool through the whole stack: a shared-prefix workload
/// (every client leads with the same system prompt) against
/// `TransformerBackend::with_kv_pool`. Every request is served; the
/// closed loop guarantees at most `clients` requests land in the first
/// admission boundary, so later admissions must hit the published
/// prefix — a nonzero hit rate and reused-token count are deterministic
/// even though exact overlap is host-timing dependent.
#[test]
fn shared_prefix_workload_reuses_cached_blocks_end_to_end() {
    use bwa_llm::coordinator::scheduler::{SchedPolicy, SchedulerConfig, TransformerBackend};
    use bwa_llm::coordinator::{serve_continuous_load, Workload};
    use bwa_llm::kvpool::KvPoolConfig;
    use bwa_llm::model::config::ModelConfig;
    use std::time::Duration;

    let cfg = ModelConfig {
        name: "it-kvpool".into(),
        vocab_size: 512,
        d_model: 128,
        n_layers: 2,
        n_heads: 2,
        d_ff: 192,
        max_seq: 64,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    };
    let ck = Checkpoint::random(&cfg, 41);
    let calib: Vec<Vec<u16>> = (0..4u16)
        .map(|s| (0..32u16).map(|t| (s * 29 + t * 13) % 512).collect())
        .collect();
    let load = Workload {
        requests: 10,
        clients: 2,
        prompt_len: 20,
        gen: 3,
        shared_prefix: 16, // 2 full 8-row blocks reusable per admission
        stagger: Duration::from_micros(500),
        seed: 19,
        long_requests: 0,
        long_prompt_len: 0,
    };
    let (name, stats, _wall) = serve_continuous_load(
        move || {
            let model = quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)).unwrap();
            TransformerBackend::with_kv_pool(
                model,
                2,
                "it-bwa-kvpool",
                KvPoolConfig {
                    blocks: 256,
                    block_tokens: 8,
                },
            )
        },
        &load,
        SchedulerConfig {
            max_active: 4,
            policy: SchedPolicy::eager(),
            spec_k: 0,
        },
    );
    assert!(name.contains("paged kv"), "{name}");
    assert_eq!(stats.requests, 10);
    assert_eq!(stats.gen_tokens, 10 * 3);
    let kv = stats.kv.expect("paged backend reports kv stats");
    assert_eq!(kv.prefix_requests, 10);
    // 2 closed-loop clients -> at most 2 admissions in the first (cold)
    // boundary; the other >= 8 requests must adopt the shared prefix.
    assert!(kv.prefix_hits >= 8, "prefix hits {} of 10", kv.prefix_hits);
    assert!(
        kv.prefix_tokens_reused >= 8 * 16,
        "each hit reuses >= 16 shared-prefix rows, got {}",
        kv.prefix_tokens_reused
    );
    assert!(kv.blocks_peak <= kv.blocks_capacity, "budget respected");
    assert!(kv.blocks_in_use > 0, "the prefix cache retains published blocks");
}

/// The TCP front-end through the whole stack: a `server::start` instance
/// over a quantized random checkpoint with a paged KV pool, driven by
/// the library [`Client`](bwa_llm::server::Client) over loopback with
/// the *same* seeded prompts the in-process driver would submit
/// ([`client_prompts`](bwa_llm::coordinator::client_prompts)). Under the
/// default greedy config every streamed continuation must be
/// bit-identical to a sequential in-process run of the same model —
/// the acceptance pin for the network path.
#[test]
fn network_server_streams_bit_identical_to_in_process_run() {
    use bwa_llm::coordinator::scheduler::{SchedPolicy, SchedulerConfig, TransformerBackend};
    use bwa_llm::coordinator::{client_prompts, Workload};
    use bwa_llm::kvpool::KvPoolConfig;
    use bwa_llm::model::config::ModelConfig;
    use bwa_llm::model::sampling::GenConfig;
    use bwa_llm::server::{self, Client, RequestLimits, ServerConfig};
    use std::net::TcpListener;
    use std::time::Duration;

    let cfg = ModelConfig {
        name: "it-net".into(),
        vocab_size: 512,
        d_model: 128,
        n_layers: 2,
        n_heads: 2,
        d_ff: 192,
        max_seq: 64,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    };
    let ck = Checkpoint::random(&cfg, 53);
    let calib: Vec<Vec<u16>> = (0..4u16)
        .map(|s| (0..32u16).map(|t| (s * 31 + t * 7) % 512).collect())
        .collect();
    let model = quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)).unwrap();

    let load = Workload {
        requests: 4,
        clients: 1,
        prompt_len: 12,
        gen: 4,
        shared_prefix: 0,
        stagger: Duration::ZERO,
        seed: 23,
        long_requests: 0,
        long_prompt_len: 0,
    };
    let prompts = client_prompts(&load, 0, load.requests);

    // in-process sequential greedy reference, before the model moves
    // into the server's backend thread
    let want: Vec<Vec<u16>> = prompts
        .iter()
        .map(|p| {
            let mut sess = model.new_session();
            let mut logits = model.prefill(&mut sess, p);
            let mut out = Vec::new();
            for _ in 0..load.gen {
                let t = bwa_llm::util::argmax(&logits) as u16;
                out.push(t);
                if out.len() == load.gen {
                    break;
                }
                logits = model.decode_step(&mut sess, t);
            }
            out
        })
        .collect();

    let pool = KvPoolConfig {
        blocks: 256,
        block_tokens: 8,
    };
    let limits = RequestLimits::for_model(&model.cfg, Some(pool));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = server::start(
        listener,
        move || TransformerBackend::with_kv_pool(model, 2, "it-net-bwa", pool),
        ServerConfig {
            scheduler: SchedulerConfig {
                max_active: 4,
                policy: SchedPolicy::eager(),
                spec_k: 0,
            },
            max_queue: 8,
            limits,
            model: "it-net".into(),
            obs: bwa_llm::obs::ObsOptions::default(),
        },
    )
    .unwrap();

    let mut client = Client::connect(&handle.addr().to_string()).unwrap();
    assert_eq!(client.server_model, "it-net");
    for (i, (prompt, want)) in prompts.iter().zip(&want).enumerate() {
        let g = client
            .generate(i as u64, prompt, load.gen, &GenConfig::default())
            .unwrap();
        assert_eq!(
            &g.tokens, want,
            "request {i}: network stream diverged from the in-process greedy run"
        );
        assert!(g.ttft <= g.total);
    }
    client.shutdown_server().unwrap();
    let stats = handle.wait();
    assert_eq!(stats.served, load.requests);
    assert_eq!(stats.scheduler.requests, load.requests);
    assert_eq!(stats.scheduler.gen_tokens, load.requests * load.gen);
    let kv = stats.scheduler.kv.expect("paged backend reports kv stats");
    assert!(kv.blocks_peak <= kv.blocks_capacity);
}

/// A request whose worst-case KV footprint exceeds the whole
/// `--kv-blocks` pool must get the typed `capacity` error over the wire
/// instead of hanging in the admission queue forever; the connection
/// stays usable and smaller requests still serve.
#[test]
fn network_capacity_rejection_over_the_wire() {
    use bwa_llm::coordinator::scheduler::{SchedPolicy, SchedulerConfig, TransformerBackend};
    use bwa_llm::kvpool::KvPoolConfig;
    use bwa_llm::model::config::ModelConfig;
    use bwa_llm::model::sampling::GenConfig;
    use bwa_llm::server::{self, Client, RequestLimits, ServeError, ServerConfig};
    use std::net::TcpListener;

    let cfg = ModelConfig {
        name: "it-cap".into(),
        vocab_size: 512,
        d_model: 128,
        n_layers: 2,
        n_heads: 2,
        d_ff: 192,
        max_seq: 64,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    };
    let ck = Checkpoint::random(&cfg, 59);
    let calib: Vec<Vec<u16>> = (0..4u16)
        .map(|s| (0..32u16).map(|t| (s * 41 + t * 5) % 512).collect())
        .collect();
    let model = quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4)).unwrap();

    // A pool so small that a full-length request cannot ever fit:
    // 12 + 39 rows -> ceil(51/8) + tail = 8 blocks x 2 layers x K/V = 32 > 24.
    let pool = KvPoolConfig {
        blocks: 24,
        block_tokens: 8,
    };
    let limits = RequestLimits::for_model(&model.cfg, Some(pool));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = server::start(
        listener,
        move || TransformerBackend::with_kv_pool(model, 2, "it-cap-bwa", pool),
        ServerConfig {
            scheduler: SchedulerConfig {
                max_active: 2,
                policy: SchedPolicy::eager(),
                spec_k: 0,
            },
            max_queue: 8,
            limits,
            model: "it-cap".into(),
            obs: bwa_llm::obs::ObsOptions::default(),
        },
    )
    .unwrap();

    let mut client = Client::connect(&handle.addr().to_string()).unwrap();
    let prompt: Vec<u16> = (0..12u16).map(|t| (t * 17) % 512).collect();
    let err = client
        .generate(0, &prompt, 40, &GenConfig::default())
        .unwrap_err();
    assert!(
        matches!(err, ServeError::Capacity(_)),
        "expected typed capacity error, got {err}"
    );

    // a request that fits the pool still serves on the same connection
    let g = client.generate(1, &prompt, 2, &GenConfig::default()).unwrap();
    assert_eq!(g.tokens.len(), 2);

    client.shutdown_server().unwrap();
    let stats = handle.wait();
    assert_eq!(stats.served, 1);
    assert_eq!(stats.rejected_capacity, 1);
}
