//! End-to-end serving driver (DESIGN.md §5, last row): load the trained
//! tiny model, stand up the dynamic-batching coordinator, and serve
//! batched next-token requests on two backends:
//!
//! 1. `pjrt` — the AOT path: JAX(L2)+Pallas(L1) were lowered to HLO text
//!    at build time; the Rust(L3) PJRT runtime compiles and executes it.
//! 2. `bwa`  — the Rust-native transformer quantized to W(1+1)A(1×4)
//!    with the INT4 KV cache.
//!
//! Reports latency percentiles and throughput for both.
//!
//! ```bash
//! cargo run --release --example serve_bwa
//! ```

use bwa_llm::coordinator::batcher::{Backend, BatcherConfig};
use bwa_llm::coordinator::{serve_workload, NativeBackend, PjrtBackend};
use bwa_llm::data::corpus::CorpusSpec;
use bwa_llm::model::checkpoint::Checkpoint;
use bwa_llm::model::Transformer;
use bwa_llm::quant::BwaQuantizer;
use bwa_llm::runtime::TransformerSession;
use std::path::Path;
use std::time::Duration;

fn main() {
    let ck_path = Path::new("artifacts/models/llama1-7b.bin");
    let ck = match Checkpoint::load(ck_path) {
        Ok(ck) => ck,
        Err(e) => {
            eprintln!("{e}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    let cfg = BatcherConfig {
        max_batch: 8,
        max_wait: Duration::from_micros(2000),
    };

    // --- backend 1: PJRT over the AOT artifact -------------------------
    if Path::new("artifacts/transformer_fp.hlo.txt").exists() {
        let ck2 = Checkpoint::load(ck_path).unwrap();
        let report = serve_workload(
            move || {
                let session = TransformerSession::load(Path::new("artifacts"), &ck2)
                    .expect("load AOT artifact");
                Box::new(PjrtBackend { session }) as Box<dyn Backend>
            },
            64,
            4,
            24,
            cfg,
            7,
        );
        println!("{report}\n");
    } else {
        eprintln!("skipping PJRT backend (no artifacts/transformer_fp.hlo.txt)");
    }

    // --- backend 2: native W(1+1)A(1x4) ---------------------------------
    let report = serve_workload(
        move || {
            let train = bwa_llm::data::corpus::train_split(&CorpusSpec::wiki(), 100_000);
            let calib = bwa_llm::data::calibration_windows(&train, 16, 96, 7);
            let model =
                bwa_llm::model::quantize_model(&ck, &BwaQuantizer::paper(), &calib, Some(4))
                    .expect("quantize");
            eprintln!(
                "quantized serving model: {:.2} mean weight bits, {} bytes",
                model.mean_weight_bits(),
                model.bytes()
            );
            Box::new(NativeBackend {
                model,
                label: "native-bwa W(1+1)A(1x4)".into(),
            }) as Box<dyn Backend>
        },
        64,
        4,
        24,
        cfg,
        7,
    );
    println!("{report}");

    // --- greedy decode demo over the quantized model --------------------
    let ck = Checkpoint::load(ck_path).unwrap();
    let fp = Transformer::fp_from_checkpoint(&ck).unwrap();
    let tok = bwa_llm::data::tokenizer::Tokenizer::new();
    let prompt = tok.encode("? ent3 rel7");
    let mut sess = fp.new_session();
    let mut seq = prompt.clone();
    for &t in &prompt {
        let logits = fp.decode_step(&mut sess, t);
        let _ = logits;
    }
    let mut sess = fp.new_session();
    let mut last = Vec::new();
    for &t in &seq {
        last = fp.decode_step(&mut sess, t);
    }
    for _ in 0..4 {
        let next = bwa_llm::util::argmax(&last) as u16;
        seq.push(next);
        last = fp.decode_step(&mut sess, next);
    }
    println!("\ngreedy decode: {}", tok.decode(&seq));
}
