//! End-to-end serving driver (DESIGN.md §5, last row): load the trained
//! tiny model, stand up the dynamic-batching coordinator, and serve
//! batched greedy-generation requests on three backends:
//!
//! 1. `pjrt`    — the AOT path: JAX(L2)+Pallas(L1) were lowered to HLO
//!    text at build time; the Rust(L3) PJRT runtime compiles and
//!    executes it.
//! 2. `bwa-seq` — the W(1+1)A(1×4) transformer on the naive per-sequence
//!    loop (a full re-prefill for every generated token).
//! 3. `bwa`     — the same quantized model on the parallel batched
//!    engine: prefill worker pool + lockstep KV-cached batched decode.
//!
//! Reports latency percentiles and request/token throughput for each, so
//! the engine's speedup over the sequential loop is visible end to end.
//!
//! ```bash
//! cargo run --release --example serve_bwa
//! ```

use bwa_llm::coordinator::batcher::{Backend, BatcherConfig};
use bwa_llm::coordinator::{
    quantize_serving_model, serve_workload, NativeBackend, ParallelBackend, PjrtBackend,
};
use bwa_llm::model::checkpoint::Checkpoint;
use bwa_llm::model::Transformer;
use bwa_llm::runtime::TransformerSession;
use std::path::Path;
use std::time::Duration;

const REQUESTS: usize = 64;
const CLIENTS: usize = 4;
const PROMPT_LEN: usize = 24;
const GEN: usize = 4;

fn quantized_model(ck: &Checkpoint) -> Transformer {
    let model = quantize_serving_model(ck, 7);
    eprintln!(
        "quantized serving model: {:.2} mean weight bits, {} bytes",
        model.mean_weight_bits(),
        model.bytes()
    );
    model
}

fn main() {
    let ck_path = Path::new("artifacts/models/llama1-7b.bin");
    let ck = match Checkpoint::load(ck_path) {
        Ok(ck) => ck,
        Err(e) => {
            eprintln!("{e}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    let cfg = BatcherConfig {
        max_batch: 8,
        max_wait: Duration::from_micros(2000),
    };

    // --- backend 1: PJRT over the AOT artifact -------------------------
    if Path::new("artifacts/transformer_fp.hlo.txt").exists() {
        let ck2 = Checkpoint::load(ck_path).unwrap();
        let report = serve_workload(
            move || {
                let session = TransformerSession::load(Path::new("artifacts"), &ck2)
                    .expect("load AOT artifact");
                Box::new(PjrtBackend { session }) as Box<dyn Backend>
            },
            REQUESTS,
            CLIENTS,
            PROMPT_LEN,
            1, // the fixed-seq artifact serves single next-token requests
            cfg,
            7,
        );
        println!("{report}\n");
    } else {
        eprintln!("skipping PJRT backend (no artifacts/transformer_fp.hlo.txt)");
    }

    // --- backend 2: W(1+1)A(1x4), naive per-sequence loop ---------------
    let report = serve_workload(
        move || {
            Box::new(NativeBackend {
                model: quantized_model(&ck),
                label: "native-bwa W(1+1)A(1x4) seq".into(),
            }) as Box<dyn Backend>
        },
        REQUESTS,
        CLIENTS,
        PROMPT_LEN,
        GEN,
        cfg,
        7,
    );
    println!("{report}\n");

    // --- backend 3: W(1+1)A(1x4), parallel batched engine ---------------
    let workers = bwa_llm::util::pool::default_threads();
    let ck3 = Checkpoint::load(ck_path).unwrap();
    let report = serve_workload(
        move || {
            let model = quantized_model(&ck3);
            let engine = ParallelBackend::new(model, workers, "native-bwa W(1+1)A(1x4)");
            Box::new(engine) as Box<dyn Backend>
        },
        REQUESTS,
        CLIENTS,
        PROMPT_LEN,
        GEN,
        cfg,
        7,
    );
    println!("{report}");

    // --- greedy decode demo over the quantized engine path ---------------
    let ck4 = Checkpoint::load(ck_path).unwrap();
    let fp = Transformer::fp_from_checkpoint(&ck4).unwrap();
    let tok = bwa_llm::data::tokenizer::Tokenizer::new();
    let prompt = tok.encode("? ent3 rel7");
    let mut sess = fp.new_session_with_capacity(prompt.len() + 4);
    let mut last = fp.prefill(&mut sess, &prompt);
    let mut seq = prompt;
    for _ in 0..4 {
        let next = bwa_llm::util::argmax(&last) as u16;
        seq.push(next);
        last = fp.decode_step(&mut sess, next);
    }
    println!("\ngreedy decode: {}", tok.decode(&seq));
}
