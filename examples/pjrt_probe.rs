fn main() {
    let dir = std::path::Path::new("artifacts");
    let ck = bwa_llm::model::checkpoint::Checkpoint::load(&dir.join("models/llama1-7b.bin")).unwrap();
    eprintln!("ckpt loaded");
    let session = bwa_llm::runtime::TransformerSession::load(dir, &ck).unwrap();
    eprintln!("session loaded");
    let tokens: Vec<u16> = vec![1; session.seq];
    let l = session.forward(&tokens).unwrap();
    eprintln!("forward ok, {} logits", l.len());
}
