//! Quantize a trained tiny-LLaMA checkpoint with the paper's method and
//! two baselines, then compare perplexity and zero-shot accuracy — a
//! miniature Table 1.
//!
//! Requires `make artifacts` (trains the model zoo).
//!
//! ```bash
//! cargo run --release --example quantize_and_eval
//! ```

use bwa_llm::baselines;
use bwa_llm::data::corpus::CorpusSpec;
use bwa_llm::eval::{evaluate, EvalBudget};
use bwa_llm::model::checkpoint::Checkpoint;
use bwa_llm::model::quantize_model;
use bwa_llm::quant::{BwaQuantizer, FpQuantizer, Quantizer};
use std::path::Path;

fn main() {
    let path = Path::new("artifacts/models/llama1-7b.bin");
    let ck = match Checkpoint::load(path) {
        Ok(ck) => ck,
        Err(e) => {
            eprintln!("{e}\nrun `make artifacts` first to train the tiny model zoo");
            std::process::exit(1);
        }
    };
    println!(
        "loaded {} ({} params, {} layers)",
        ck.config.name,
        ck.config.param_count(),
        ck.config.n_layers
    );

    let train = bwa_llm::data::corpus::train_split(&CorpusSpec::wiki(), 200_000);
    let calib = bwa_llm::data::calibration_windows(&train, 16, 96, 17);
    let budget = EvalBudget::quick();

    let methods: Vec<(&str, Box<dyn Quantizer>)> = vec![
        ("FP16", Box::new(FpQuantizer)),
        ("Atom W2A4", baselines::by_name("atom-w2a4").unwrap()),
        ("GPTQ W1A4", baselines::by_name("gptq-w1a4").unwrap()),
        ("Ours W(1+1)A(1x4)", Box::new(BwaQuantizer::paper())),
    ];

    println!("\n{:<20} {:>9} {:>9} {:>9} {:>8}", "method", "wiki ppl", "ptb ppl", "c4 ppl", "zs avg");
    for (label, q) in methods {
        let kv = if label == "FP16" { None } else { Some(4) };
        let model = quantize_model(&ck, q.as_ref(), &calib, kv).expect("quantize");
        let r = evaluate(&model, label, &budget, 17);
        println!(
            "{:<20} {:>9.2} {:>9.2} {:>9.2} {:>7.1}%",
            label,
            r.ppl[0].1,
            r.ppl[1].1,
            r.ppl[2].1,
            r.zs_avg * 100.0
        );
    }
    println!("\nExpected shape (paper Table 1): ours ≈ FP16, GPTQ-W1A4 collapses,");
    println!("Atom-W2A4 in between.");
}
