//! The paper's speed claim in miniature (Figure 3): time the W(1+1)A(1×4)
//! popcount GEMM against the INT8/INT4 dense kernels on one LLaMA layer
//! shape and print the speedup.
//!
//! ```bash
//! cargo run --release --example kernel_speedup
//! ```

use bwa_llm::exps::kernel_bench::{prepare_synthetic, synthetic_bwa};
use bwa_llm::kernels::dense::{Int4Gemm, Int8Gemm};
use bwa_llm::tensor::Tensor;
use bwa_llm::util::bench::{black_box, Bencher};
use bwa_llm::util::rng::Rng;

fn main() {
    let (out_f, in_f, m) = (4096, 4096, 8);
    let mut rng = Rng::new(1);
    let bencher = Bencher::default();

    println!("GEMM {out_f}x{in_f}, batch {m} tokens (LLaMA-7B attention shape)\n");

    let lin = synthetic_bwa(out_f, in_f, 128, 1, 3);
    let gemm = prepare_synthetic(&lin);
    let x = Tensor::from_vec(&[m, in_f], rng.normal_vec_f32(m * in_f, 0.0, 1.0));
    let acts = gemm.pack_activations(&x);
    let ours = bencher.run("W(1+1)A(1x4) popcount", || black_box(gemm.gemm_packed(&acts)));
    println!("{}", ours.report());

    let w = Tensor::from_vec(&[out_f, in_f], rng.normal_vec_f32(out_f * in_f, 0.0, 0.05));
    let g8 = Int8Gemm::prepare(&w);
    let int8 = bencher.run("INT8 dense (W8A8 stand-in)", || black_box(g8.forward(&x)));
    println!("{}", int8.report());

    let g4 = Int4Gemm::prepare(&w);
    let int4 = bencher.run("INT4 dense (W4A4 stand-in)", || black_box(g4.forward(&x)));
    println!("{}", int4.report());

    println!(
        "\nspeedup: {:.2}x vs INT8, {:.2}x vs INT4 (paper reports ~3x vs CUTLASS INT4 on A6000)",
        int8.median_ns / ours.median_ns,
        int4.median_ns / ours.median_ns
    );
}
