//! Quickstart: quantize one linear layer to W(1+1)A(1×4) and run both the
//! fake-quant and the popcount-binary forward.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use bwa_llm::kernels::bwa_gemm::BwaGemm;
use bwa_llm::quant::binarize::{quantize_bwa, BwaConfig};
use bwa_llm::tensor::{matmul_wt, Tensor};
use bwa_llm::util::prop::rel_err;
use bwa_llm::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(42);
    let (out_f, in_f) = (256, 256);

    // A random weight matrix and LLM-like calibration activations
    // (heavy-tailed channels).
    let w = Tensor::from_vec(&[out_f, in_f], rng.normal_vec_f32(out_f * in_f, 0.0, 0.05));
    let mut calib = Tensor::zeros(&[128, in_f]);
    for v in &mut calib.data {
        *v = rng.normal_f32(0.0, 1.0);
    }
    for t in 0..128 {
        calib.data[t * in_f + 7] *= 18.0; // an outlier channel
    }

    // Algorithm 1: reorder → Hessian → EM fine-grained binarization →
    // GPTQ compensation → INT8 outliers → bit packing.
    let t0 = std::time::Instant::now();
    let lin = quantize_bwa(&w, &calib, &BwaConfig::paper());
    println!(
        "quantized {out_f}x{in_f} layer in {:.2}s — {:.2} bits/weight, {} bytes",
        t0.elapsed().as_secs_f64(),
        lin.weight_bits_per_element(),
        lin.bytes()
    );

    // Evaluate on fresh tokens.
    let x = Tensor::from_vec(&[4, in_f], rng.normal_vec_f32(4 * in_f, 0.0, 1.0));
    let y_fp = matmul_wt(&x, &w);
    let y_fake = lin.forward(&x);

    // The popcount path (Eq. 5–7): AND + POPCNT over packed bit planes.
    let gemm = BwaGemm::prepare(&lin);
    let y_bits = gemm.forward(&x);

    println!("fake-quant vs FP relative error:   {:.4}", rel_err(&y_fake.data, &y_fp.data));
    println!("binary path vs fake-quant error:   {:.6}", rel_err(&y_bits.data, &y_fake.data));
    println!(
        "outlier channels kept in INT8:     {} of {}",
        lin.outlier.k, in_f
    );
    assert!(rel_err(&y_bits.data, &y_fake.data) < 0.02);
    println!("OK — the bit path reproduces the fake-quant math.");
}
