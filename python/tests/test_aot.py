"""AOT pipeline tests: lowering produces loadable HLO text."""

import json

import numpy as np

from compile import aot, common


def test_transformer_lowering_produces_hlo_text():
    cfg = dict(common.TINY, n_layers=1, d_model=64, n_heads=2, d_ff=128,
               vocab_size=64, max_seq=64)
    hlo, manifest = aot.lower_transformer_fp(cfg, seq=16)
    assert hlo.startswith("HloModule")
    assert manifest["inputs"][0] == "tokens"
    # name-sorted parameter order (matches Rust BTreeMap order)
    names = manifest["inputs"][1:]
    assert names == sorted(names)
    assert len(manifest["shapes"]) == len(manifest["inputs"])


def test_kernel_lowering_produces_hlo_text():
    hlo, manifest = aot.lower_bwa_kernel(tokens=2, out_f=64, in_f=64,
                                         group_size=64)
    assert hlo.startswith("HloModule")
    assert manifest["inputs"] == [
        "planes", "mu", "shift", "qbits", "mbits", "alpha", "beta", "wsum"
    ]


def test_manifest_is_json_serializable():
    hlo, manifest = aot.lower_bwa_kernel(tokens=1, out_f=64, in_f=64,
                                         group_size=64)
    json.dumps(manifest)
    assert "parameter" in hlo or "ENTRY" in hlo


def test_lowered_hlo_has_all_params():
    cfg = dict(common.TINY, n_layers=1, d_model=64, n_heads=2, d_ff=128,
               vocab_size=64, max_seq=64)
    hlo, manifest = aot.lower_transformer_fp(cfg, seq=8)
    n_params = len(manifest["inputs"])
    # every input appears as an HLO entry parameter
    assert hlo.count("parameter(") >= n_params
