"""L2 tests: tiny-LLaMA forward properties + a short training sanity run."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import common, model


def small_cfg():
    return dict(common.TINY, n_layers=1, d_model=64, n_heads=2, d_ff=128,
                vocab_size=64, max_seq=64)


def test_forward_shape_and_determinism():
    cfg = small_cfg()
    p = model.init_params(cfg, 0)
    toks = jnp.asarray(np.arange(10) % 64)
    a = model.forward(cfg, p, toks)
    b = model.forward(cfg, p, toks)
    assert a.shape == (10, 64)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_causality():
    cfg = small_cfg()
    p = model.init_params(cfg, 1)
    t1 = jnp.asarray([3, 7, 11, 13, 17])
    t2 = jnp.asarray([3, 7, 11, 62, 1])
    a = np.asarray(model.forward(cfg, p, t1))
    b = np.asarray(model.forward(cfg, p, t2))
    np.testing.assert_allclose(a[:3], b[:3], rtol=1e-5, atol=1e-5)


def test_loss_decreases_with_sgd():
    cfg = small_cfg()
    p = model.init_params(cfg, 2)
    rng = np.random.default_rng(0)
    batch = jnp.asarray(rng.integers(0, 64, size=(4, 33)))
    lg = jax.jit(jax.value_and_grad(lambda pp: model.loss_fn(cfg, pp, batch)))
    l0, _ = lg(p)
    for _ in range(25):
        loss, g = lg(p)
        p = {k: np.asarray(p[k]) - 0.5 * np.asarray(g[k]) for k in p}
    l1, _ = lg(p)
    assert float(l1) < float(l0) * 0.9, (float(l0), float(l1))


def test_bwa_forward_tracks_fp():
    cfg = small_cfg()
    p = model.init_params(cfg, 3)
    toks = jnp.asarray(np.arange(12) % 64)
    fp = np.asarray(model.forward(cfg, p, toks))
    bwa = model.bwa_sim_params(cfg, p)
    qn = np.asarray(model.forward_bwa(cfg, p, bwa, toks))
    rel = np.abs(qn - fp).mean() / (np.abs(fp).mean() + 1e-9)
    assert rel < 0.8, rel


def test_rope_preserves_norm():
    x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 64)),
                    dtype=jnp.float32)
    pos = jnp.arange(4, dtype=jnp.float32)
    y = model.rope(x, 2, 10000.0, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=1),
        np.linalg.norm(np.asarray(y), axis=1),
        rtol=1e-4,
    )


def test_checkpoint_roundtrip():
    cfg = small_cfg()
    p = model.init_params(cfg, 4)
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m.bin")
        common.save_checkpoint(path, cfg, p)
        cfg2, p2 = common.load_checkpoint(path)
        assert cfg2["d_model"] == cfg["d_model"]
        for k in p:
            np.testing.assert_array_equal(np.asarray(p[k], np.float32),
                                          p2[k])
