"""L1 correctness: the Pallas BWA kernel vs the pure-jnp oracle.

This is the core build-time correctness signal; hypothesis sweeps shapes
and value distributions.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bwa_linear import (
    bwa_linear,
    fold_coefficients,
    weight_row_sums,
)


def run_pair(rng, t, o, n, g):
    q, m, a, b = ref.random_bwa_layer(rng, o, n, g)
    x = rng.standard_normal((t, n)).astype(np.float32) * (
        0.5 + rng.random()
    )
    planes, mu, shift = ref.quantize_acts_int4(x)
    wsum = weight_row_sums(q, m, a, b, g)
    y_ref = np.asarray(ref.bwa_linear_ref(planes, mu, shift, q, m, a, b, g))
    y_ker = np.asarray(
        bwa_linear(
            jnp.asarray(planes), jnp.asarray(mu), jnp.asarray(shift),
            jnp.asarray(q), jnp.asarray(m), jnp.asarray(a), jnp.asarray(b),
            wsum, group_size=g,
            row_tile=min(64, o),
        )
    )
    return y_ref, y_ker


def test_kernel_matches_ref_basic():
    rng = np.random.default_rng(0)
    y_ref, y_ker = run_pair(rng, t=3, o=128, n=192, g=64)
    np.testing.assert_allclose(y_ker, y_ref, rtol=1e-4, atol=1e-4)


def test_kernel_single_token_single_tile():
    rng = np.random.default_rng(1)
    y_ref, y_ker = run_pair(rng, t=1, o=64, n=64, g=64)
    np.testing.assert_allclose(y_ker, y_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    t=st.integers(1, 4),
    o_tiles=st.integers(1, 3),
    groups=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(t, o_tiles, groups, seed):
    rng = np.random.default_rng(seed)
    o = 64 * o_tiles
    n = 64 * groups
    y_ref, y_ker = run_pair(rng, t=t, o=o, n=n, g=64)
    np.testing.assert_allclose(y_ker, y_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), gs=st.sampled_from([32, 64, 128]))
def test_kernel_group_sizes(seed, gs):
    rng = np.random.default_rng(seed)
    y_ref, y_ker = run_pair(rng, t=2, o=64, n=2 * gs, g=gs)
    np.testing.assert_allclose(y_ker, y_ref, rtol=1e-4, atol=1e-4)


def test_act_quantization_error_bounded():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((5, 256)).astype(np.float32)
    planes, mu, shift = ref.quantize_acts_int4(x)
    xhat = np.asarray(ref.dequantize_acts(planes, mu, shift))
    scale = mu[:, 0]  # mu_0 == RTN step
    assert np.all(np.abs(x - xhat) <= scale[:, None] * 0.5 + 1e-5)


def test_planes_are_binary():
    rng = np.random.default_rng(4)
    x = rng.standard_normal((2, 64)).astype(np.float32)
    planes, _, _ = ref.quantize_acts_int4(x)
    assert set(np.unique(planes)) <= {0.0, 1.0}


def test_fold_coefficients_shape():
    a = np.ones((2, 3, 2), np.float32)
    b = np.zeros((2, 3, 2), np.float32)
    c = np.asarray(fold_coefficients(a, b))
    assert c.shape == (2, 3, 4)
    np.testing.assert_allclose(c[..., 0], 2.0)  # c1 = 2 alpha1
    np.testing.assert_allclose(c[..., 1], -1.0)  # c2 = beta1 - alpha1


def test_weight_dequant_uses_fine_group_bit():
    # s=1 elements must use alpha[...,1]/beta[...,1]
    o, n, g = 1, 64, 64
    q = np.ones((o, n), np.float32)
    m = np.zeros((o, n), np.float32)
    m[0, :32] = 1.0
    alpha = np.zeros((1, 1, 2), np.float32)
    beta = np.zeros((1, 1, 2), np.float32)
    beta[0, 0, 0] = 5.0  # s=0 value
    beta[0, 0, 1] = -7.0  # s=1 value
    w = np.asarray(ref.dequantize_weights(q, m, alpha, beta, g))
    assert np.all(w[0, :32] == -7.0)
    assert np.all(w[0, 32:] == 5.0)


@pytest.mark.parametrize("t", [1, 3])
def test_zero_activations_give_shift_only(t):
    rng = np.random.default_rng(5)
    o, n, g = 64, 64, 64
    q, m, a, b = ref.random_bwa_layer(rng, o, n, g)
    x = np.zeros((t, n), np.float32)
    planes, mu, shift = ref.quantize_acts_int4(x)
    wsum = weight_row_sums(q, m, a, b, g)
    y = np.asarray(
        bwa_linear(jnp.asarray(planes), jnp.asarray(mu), jnp.asarray(shift),
                   jnp.asarray(q), jnp.asarray(m), jnp.asarray(a),
                   jnp.asarray(b), wsum, group_size=g, row_tile=64))
    # x == 0 -> quantized planes may carry the zero code; dequant must be ~0
    y_ref = np.asarray(ref.bwa_linear_ref(planes, mu, shift, q, m, a, b, g))
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    assert np.all(np.abs(y) < 1e-3)
