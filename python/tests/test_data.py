"""Cross-language data contract: token files written by the Rust datagen
load correctly, and checkpoints written here load in Rust (exercised via
the bwa binary when present)."""

import os
import subprocess
from pathlib import Path

import numpy as np
import pytest

from compile import common

REPO = Path(__file__).resolve().parents[2]


def test_rust_token_files_load():
    p = REPO / "artifacts/data/wiki_train.tok"
    if not p.exists():
        pytest.skip("artifacts/data not generated yet (run `make artifacts`)")
    toks = common.load_tokens(p)
    assert toks.dtype == np.int32
    assert len(toks) > 1000
    assert toks.min() >= 0 and toks.max() < 512


def test_rust_binary_reads_python_checkpoint(tmp_path):
    bwa = REPO / "target/release/bwa"
    if not bwa.exists():
        pytest.skip("bwa binary not built")
    from compile import model
    cfg = dict(common.TINY, n_layers=1, d_model=64, n_heads=2, d_ff=128,
               vocab_size=512, max_seq=64, name="pytest-tiny")
    p = model.init_params(cfg, 9)
    ck = tmp_path / "m.bin"
    common.save_checkpoint(ck, cfg, p)
    out = subprocess.run(
        [str(bwa), "eval", "--model", str(ck), "--method", "fp16",
         "--quick"],
        capture_output=True, text=True, timeout=600, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr
    assert "fp16" in out.stdout
