"""Pure-jnp oracle for the W(1+1)A(1x4) binarized linear layer.

This is the correctness ground truth for the Pallas kernel: dequantize the
bit representation back to floats and do an ordinary matmul. The kernel
(`bwa_linear.py`) must match this to float tolerance; pytest enforces it,
including a hypothesis sweep over shapes.
"""

import jax.numpy as jnp
import numpy as np


def dequantize_weights(qbits, mbits, alpha, beta, group_size):
    """What[o, n] = alpha[o, g, s]*(2q-1) + beta[o, g, s] with s = m[o, n].

    qbits/mbits: [O, N] in {0,1}; alpha/beta: [O, G, 2]."""
    _, n = qbits.shape
    sign = 2.0 * qbits - 1.0
    s = mbits.astype(jnp.int32)  # fine-group bit
    gi = jnp.arange(n) // group_size  # group index per channel
    a = alpha[:, gi, :]  # [O, N, 2]
    b = beta[:, gi, :]
    a_sel = jnp.take_along_axis(a, s[:, :, None], axis=2)[:, :, 0]
    b_sel = jnp.take_along_axis(b, s[:, :, None], axis=2)[:, :, 0]
    return a_sel * sign + b_sel


def dequantize_acts(planes, mu, shift):
    """xhat[t, n] = sum_a mu[t, a]*b[t, a, n] + shift[t]."""
    return jnp.einsum("ta,tan->tn", mu, planes) + shift[:, None]


def bwa_linear_ref(planes, mu, shift, qbits, mbits, alpha, beta, group_size):
    """Reference forward: y[t, o] = xhat @ What^T."""
    w_hat = dequantize_weights(qbits, mbits, alpha, beta, group_size)
    x_hat = dequantize_acts(planes, mu, shift)
    return x_hat @ w_hat.T


def quantize_acts_int4(x):
    """RTN INT4 (asym, zero-inclusive range) -> bit planes, per token.

    Returns (planes [T, 4, N] float {0,1}, mu [T, 4], shift [T]).
    Mirrors rust/src/quant/actquant.rs with BalanceMode::None."""
    x = np.asarray(x, dtype=np.float32)
    lo = np.minimum(x.min(axis=1), 0.0)
    hi = np.maximum(x.max(axis=1), 0.0)
    scale = np.where(hi - lo > 0, (hi - lo) / 15.0, 1.0).astype(np.float32)
    zero = np.clip(np.round(-lo / scale), 0, 15).astype(np.int32)
    q = np.clip(np.round(x / scale[:, None]) + zero[:, None], 0, 15).astype(
        np.int32
    )
    planes = np.stack([(q >> a) & 1 for a in range(4)], axis=1).astype(
        np.float32
    )
    mu = (scale[:, None] * (2.0 ** np.arange(4))[None, :]).astype(np.float32)
    shift = (-scale * zero).astype(np.float32)
    return planes, mu, shift


def random_bwa_layer(rng, out_f, in_f, group_size):
    """Random but well-formed (q, m, alpha, beta) for kernel tests."""
    g = in_f // group_size
    qbits = (rng.random((out_f, in_f)) < 0.5).astype(np.float32)
    mbits = (rng.random((out_f, in_f)) < 0.4).astype(np.float32)
    alpha = (0.02 + 0.05 * rng.random((out_f, g, 2))).astype(np.float32)
    beta = (0.04 * rng.standard_normal((out_f, g, 2))).astype(np.float32)
    return qbits, mbits, alpha, beta
