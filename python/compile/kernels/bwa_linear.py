"""Layer-1 Pallas kernel: the W(1+1)A(1x4) binarized fully-connected layer
(paper Eq. 5-7).

The kernel consumes the *bit* representation directly: activation bit
planes b_a, weight sign bits q, fine-group bitmap m (all {0,1} tensors) and
the per-(row, group, s) affine parameters. Per output-row tile it computes,
for every group g and plane a, the three bitwise inner products

    v  = sum_i q*b       (popc(q & b)   on real hardware)
    v1 = sum_i q*b*m     (popc(q & b & m))
    r1 = sum_i b*m       (popc(b & m))
    r  = sum_i b         (popc(b), token-only)

and folds them with c1 = 2*alpha_1, c2 = beta_1 - alpha_1, c3 = 2*alpha_0,
c4 = beta_0 - alpha_0:

    y[t, o] += sum_a mu[t,a] * (c3*v + (c1-c3)*v1 + c4*(r-r1) + c2*r1)
             + shift[t] * wsum[o]

TPU adaptation (DESIGN.md "Hardware adaptation"): the products above are
contractions of {0,1}-valued operands, expressed as jnp.dot so they lower
onto the MXU systolic array; the BlockSpec streams (row-tile x full
channel) tiles HBM->VMEM once per tile and reuses them across all 4+1
planes, which is the same bandwidth amortization the CUDA kernel gets from
warp-level AND+popc over 128-bit fragments. interpret=True everywhere:
the CPU PJRT plugin cannot execute Mosaic custom-calls (see
/opt/xla-example/README.md), so correctness runs through the interpreter
and the HLO export stays executable from the Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 64


def _bwa_kernel(planes_ref, mu_ref, shift_ref, q_ref, m_ref, c_ref, wsum_ref,
                out_ref, *, group_size):
    """One (token, row-tile) grid cell.

    planes_ref: [A, N]   activation bit planes of this token
    mu_ref:     [A]      plane scales
    shift_ref:  [1]      shift coefficient
    q_ref:      [BO, N]  sign bits of the row tile
    m_ref:      [BO, N]  fine-group bitmap
    c_ref:      [BO, G, 4] folded coefficients (c1, c2, c3, c4)
    wsum_ref:   [BO]     row sums of dequantized weights
    out_ref:    [BO]     output slice y[t, tile]
    """
    planes = planes_ref[0]
    q = q_ref[...]
    m = m_ref[...]
    c = c_ref[...]
    bo, n = q.shape
    a = planes.shape[0]
    g = n // group_size

    # reshape into groups: [BO, G, Z] and [A, G, Z]
    qg = q.reshape(bo, g, group_size)
    mg = m.reshape(bo, g, group_size)
    bg = planes.reshape(a, g, group_size)

    # v / v1 / r1 as MXU-friendly contractions over the channel axis
    v = jnp.einsum("ogz,agz->oga", qg, bg, preferred_element_type=jnp.float32)
    v1 = jnp.einsum("ogz,agz->oga", qg * mg, bg,
                    preferred_element_type=jnp.float32)
    r1 = jnp.einsum("ogz,agz->oga", mg, bg,
                    preferred_element_type=jnp.float32)
    r = jnp.sum(bg, axis=2)  # [A, G] token-only

    c1 = c[:, :, 0:1]
    c2 = c[:, :, 1:2]
    c3 = c[:, :, 2:3]
    c4 = c[:, :, 3:4]
    contrib = (c3 * v + (c1 - c3) * v1 + c4 * (r.T[None, :, :] - r1)
               + c2 * r1)  # [BO, G, A]
    mu = mu_ref[0]
    y = jnp.einsum("oga,a->o", contrib, mu) + shift_ref[0] * wsum_ref[...]
    out_ref[0, :] = y


def fold_coefficients(alpha, beta):
    """(alpha, beta) [O, G, 2] -> folded [O, G, 4] = (c1, c2, c3, c4)."""
    c1 = 2.0 * alpha[:, :, 1]
    c2 = beta[:, :, 1] - alpha[:, :, 1]
    c3 = 2.0 * alpha[:, :, 0]
    c4 = beta[:, :, 0] - alpha[:, :, 0]
    return jnp.stack([c1, c2, c3, c4], axis=-1)


def weight_row_sums(qbits, mbits, alpha, beta, group_size):
    """wsum[o] = sum_n What[o, n] — multiplies the shift plane."""
    from . import ref

    return jnp.sum(
        ref.dequantize_weights(qbits, mbits, alpha, beta, group_size), axis=1
    )


@functools.partial(jax.jit, static_argnames=("group_size", "row_tile"))
def bwa_linear(planes, mu, shift, qbits, mbits, alpha, beta, wsum,
               group_size=64, row_tile=DEFAULT_ROW_TILE):
    """Binarized FC forward via the Pallas kernel.

    planes: [T, A, N]; mu: [T, A]; shift: [T];
    qbits/mbits: [O, N]; alpha/beta: [O, G, 2]; wsum: [O]  ->  y [T, O].
    """
    t, a, n = planes.shape
    o = qbits.shape[0]
    assert n % group_size == 0, "N must be a multiple of group_size"
    row_tile = min(row_tile, o)
    assert o % row_tile == 0, "O must be a multiple of row_tile"
    g = n // group_size
    coef = fold_coefficients(alpha, beta)

    grid = (t, o // row_tile)
    kernel = functools.partial(_bwa_kernel, group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, a, n), lambda ti, oi: (ti, 0, 0)),
            pl.BlockSpec((1, a), lambda ti, oi: (ti, 0)),
            pl.BlockSpec((1,), lambda ti, oi: (ti,)),
            pl.BlockSpec((row_tile, n), lambda ti, oi: (oi, 0)),
            pl.BlockSpec((row_tile, n), lambda ti, oi: (oi, 0)),
            pl.BlockSpec((row_tile, g, 4), lambda ti, oi: (oi, 0, 0)),
            pl.BlockSpec((row_tile,), lambda ti, oi: (oi,)),
        ],
        out_specs=pl.BlockSpec((1, row_tile), lambda ti, oi: (ti, oi)),
        out_shape=jax.ShapeDtypeStruct((t, o), jnp.float32),
        interpret=True,
    )(planes, mu, shift, qbits, mbits, coef, wsum)
