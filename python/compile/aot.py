"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO *text* artifacts for the
Rust PJRT runtime (L3).

HLO text, NOT serialized protos: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Artifacts:
  transformer_fp.hlo.txt — tiny-LLaMA forward, tokens[S] + name-sorted
      parameter list -> (logits,). The serving coordinator executes this.
  bwa_linear.hlo.txt     — the standalone Pallas W(1+1)A(1x4) kernel for
      one tiny-model projection shape, lowered through the same pipeline.
  manifest.json          — input names/shapes per artifact so the Rust
      loader can feed parameters in the right order.
"""

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import common, model
from .kernels.bwa_linear import bwa_linear, fold_coefficients


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_transformer_fp(cfg, seq):
    names = sorted(model.init_params(cfg, 0))
    shapes = {
        n: np.asarray(model.init_params(cfg, 0)[n]).shape for n in names
    }

    def fn(tokens, *plist):
        p = dict(zip(names, plist))
        return (model.forward(cfg, p, tokens),)

    specs = [jax.ShapeDtypeStruct((seq,), jnp.int32)] + [
        jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names
    ]
    lowered = jax.jit(fn).lower(*specs)
    manifest = {
        "inputs": ["tokens"] + names,
        "shapes": [[seq]] + [list(shapes[n]) for n in names],
        "seq": seq,
        "vocab": cfg["vocab_size"],
        "config": cfg,
    }
    return to_hlo_text(lowered), manifest


def lower_bwa_kernel(tokens, out_f, in_f, group_size):
    g = in_f // group_size

    def fn(planes, mu, shift, qbits, mbits, alpha, beta, wsum):
        return (
            bwa_linear(planes, mu, shift, qbits, mbits, alpha, beta, wsum,
                       group_size=group_size, row_tile=64),
        )

    f32 = jnp.float32
    specs = [
        jax.ShapeDtypeStruct((tokens, 4, in_f), f32),
        jax.ShapeDtypeStruct((tokens, 4), f32),
        jax.ShapeDtypeStruct((tokens,), f32),
        jax.ShapeDtypeStruct((out_f, in_f), f32),
        jax.ShapeDtypeStruct((out_f, in_f), f32),
        jax.ShapeDtypeStruct((out_f, g, 2), f32),
        jax.ShapeDtypeStruct((out_f, g, 2), f32),
        jax.ShapeDtypeStruct((out_f,), f32),
    ]
    lowered = jax.jit(fn).lower(*specs)
    manifest = {
        "inputs": ["planes", "mu", "shift", "qbits", "mbits", "alpha",
                   "beta", "wsum"],
        "shapes": [list(s.shape) for s in specs],
        "tokens": tokens,
        "out_features": out_f,
        "in_features": in_f,
        "group_size": group_size,
    }
    return to_hlo_text(lowered), manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seq", type=int, default=96)
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    cfg = common.TINY
    manifest = {}

    hlo, m = lower_transformer_fp(cfg, args.seq)
    (out / "transformer_fp.hlo.txt").write_text(hlo)
    manifest["transformer_fp.hlo.txt"] = m
    print(f"wrote transformer_fp.hlo.txt ({len(hlo)} chars)")

    hlo, m = lower_bwa_kernel(tokens=4, out_f=cfg["d_model"],
                              in_f=cfg["d_model"], group_size=64)
    (out / "bwa_linear.hlo.txt").write_text(hlo)
    manifest["bwa_linear.hlo.txt"] = m
    print(f"wrote bwa_linear.hlo.txt ({len(hlo)} chars)")

    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print("wrote manifest.json")
    # keep the folded-coefficient helper exercised at build time
    _ = fold_coefficients(np.zeros((1, 1, 2), np.float32),
                          np.zeros((1, 1, 2), np.float32))


if __name__ == "__main__":
    main()
