"""Layer-2: tiny-LLaMA forward/backward in JAX.

Architecture mirrors rust/src/model/mod.rs exactly (RMSNorm -> MHA with
RoPE -> residual -> RMSNorm -> SwiGLU -> residual; separate FP embedding
and LM head; no biases), so checkpoints trained here load and evaluate in
the Rust runtime unchanged.

Two forward variants:
 - `forward`      — plain FP (training + the AOT fp artifact);
 - `forward_bwa`  — same graph with every linear routed through the
   Layer-1 Pallas kernel on a fake W(1+1)A(1x4) parameterization
   (`bwa_sim_params`), proving L1 composes into L2 and giving the AOT
   binarized artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.bwa_linear import bwa_linear, weight_row_sums

PARAM_ORDER_NOTE = "tensors are name-sorted (BTreeMap order) in checkpoints"


# ---------------------------------------------------------------------------
# parameter init / naming (names match the Rust checkpoint reader)
# ---------------------------------------------------------------------------

def init_params(cfg, seed):
    rng = np.random.default_rng(seed)
    d, ff, v = cfg["d_model"], cfg["d_ff"], cfg["vocab_size"]
    std = 0.06

    def mat(o, i):
        return (std * rng.standard_normal((o, i))).astype(np.float32)

    p = {"embed": (0.5 * rng.standard_normal((v, d))).astype(np.float32),
         "lm_head": mat(v, d),
         "final_norm": np.ones(d, np.float32)}
    for l in range(cfg["n_layers"]):
        p[f"layers.{l}.attn_norm"] = np.ones(d, np.float32)
        p[f"layers.{l}.mlp_norm"] = np.ones(d, np.float32)
        p[f"layers.{l}.wq"] = mat(d, d)
        p[f"layers.{l}.wk"] = mat(d, d)
        p[f"layers.{l}.wv"] = mat(d, d)
        p[f"layers.{l}.wo"] = mat(d, d)
        p[f"layers.{l}.gate"] = mat(ff, d)
        p[f"layers.{l}.up"] = mat(ff, d)
        p[f"layers.{l}.down"] = mat(d, ff)
    return p


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, gain, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def rope(x, n_heads, theta, positions):
    """x: [T, d]; adjacent-pair rotation within each head (matches Rust)."""
    t, d = x.shape
    hd = d // n_heads
    xh = x.reshape(t, n_heads, hd // 2, 2)
    i = jnp.arange(hd // 2)
    freq = 1.0 / (theta ** (2.0 * i / hd))          # [hd/2]
    ang = positions[:, None] * freq[None, :]          # [T, hd/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    a = xh[..., 0]
    b = xh[..., 1]
    ra = a * cos[:, None, :] - b * sin[:, None, :]
    rb = a * sin[:, None, :] + b * cos[:, None, :]
    return jnp.stack([ra, rb], axis=-1).reshape(t, d)


def causal_attention(q, k, v, n_heads):
    t, d = q.shape
    hd = d // n_heads
    qh = q.reshape(t, n_heads, hd).transpose(1, 0, 2)
    kh = k.reshape(t, n_heads, hd).transpose(1, 0, 2)
    vh = v.reshape(t, n_heads, hd).transpose(1, 0, 2)
    scores = jnp.einsum("htd,hsd->hts", qh, kh) / jnp.sqrt(
        jnp.asarray(hd, q.dtype))
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hts,hsd->htd", probs, vh)
    return out.transpose(1, 0, 2).reshape(t, d)


def _block(cfg, p, l, x, linear):
    eps = cfg["rmsnorm_eps"]
    nh = cfg["n_heads"]
    pos = jnp.arange(x.shape[0], dtype=jnp.float32)
    h = rmsnorm(x, p[f"layers.{l}.attn_norm"], eps)
    q = rope(linear(h, f"layers.{l}.wq"), nh, cfg["rope_theta"], pos)
    k = rope(linear(h, f"layers.{l}.wk"), nh, cfg["rope_theta"], pos)
    v = linear(h, f"layers.{l}.wv")
    attn = causal_attention(q, k, v, nh)
    x = x + linear(attn, f"layers.{l}.wo")
    h = rmsnorm(x, p[f"layers.{l}.mlp_norm"], eps)
    act = jax.nn.silu(linear(h, f"layers.{l}.gate")) * linear(
        h, f"layers.{l}.up")
    return x + linear(act, f"layers.{l}.down")


def forward(cfg, p, tokens):
    """FP forward: tokens [T] int32 -> logits [T, vocab]."""
    p = {k: jnp.asarray(v) for k, v in p.items()}

    def linear(x, name):
        return x @ p[name].T

    x = p["embed"][tokens]
    for l in range(cfg["n_layers"]):
        x = _block(cfg, p, l, x, linear)
    x = rmsnorm(x, p["final_norm"], cfg["rmsnorm_eps"])
    return x @ p["lm_head"].T


def loss_fn(cfg, p, tokens):
    """Mean next-token cross entropy over a [B, T] batch."""
    def one(seq):
        logits = forward(cfg, p, seq[:-1])
        lp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(lp, seq[1:, None], axis=1).mean()

    return jax.vmap(one)(tokens).mean()


# ---------------------------------------------------------------------------
# BWA-simulated forward (L1 kernel inside L2)
# ---------------------------------------------------------------------------

def bwa_sim_params(cfg, p, group_size=64):
    """Binarize every linear of `p` into kernel-ready (q, m, alpha, beta,
    wsum) using a fast median-split parameterization (the *real* EM
    quantizer lives in Rust; this build-time variant exercises the same
    kernel contract)."""
    out = {}
    names = [k for k in p if k.startswith("layers.") and
             k.split(".")[-1] in ("wq", "wk", "wv", "wo", "gate", "up",
                                  "down")]
    for name in names:
        w = np.asarray(p[name])
        o, n = w.shape
        g = n // group_size
        wg = w.reshape(o, g, group_size)
        med = np.median(wg, axis=2, keepdims=True)
        qbits = (wg >= med).astype(np.float32)
        dev = np.abs(wg - med)
        thr = np.median(dev, axis=2, keepdims=True)
        mbits = (dev > thr).astype(np.float32)  # s=1: far-from-center group
        alpha = np.zeros((o, g, 2), np.float32)
        beta = np.zeros((o, g, 2), np.float32)
        for s in (0, 1):
            sel = mbits == s
            pos_pick = sel & (qbits == 1.0)
            neg_pick = sel & (qbits == 0.0)
            pos_cnt = pos_pick.sum(axis=2)
            neg_cnt = neg_pick.sum(axis=2)
            hi = np.where(pos_cnt > 0,
                          (wg * pos_pick).sum(axis=2) / np.maximum(pos_cnt, 1),
                          0.0)
            lo = np.where(neg_cnt > 0,
                          (wg * neg_pick).sum(axis=2) / np.maximum(neg_cnt, 1),
                          0.0)
            alpha[:, :, s] = (hi - lo) / 2.0
            beta[:, :, s] = (hi + lo) / 2.0
        entry = {
            "qbits": qbits.reshape(o, n),
            "mbits": mbits.reshape(o, n),
            "alpha": alpha,
            "beta": beta,
        }
        entry["wsum"] = np.asarray(
            weight_row_sums(entry["qbits"], entry["mbits"], alpha, beta,
                            group_size))
        out[name] = entry
    return out


def _row_tile(o):
    for t in (64, 32, 16, 8, 4, 2, 1):
        if o % t == 0:
            return t
    return 1


def forward_bwa(cfg, p, bwa, tokens, group_size=64):
    """Forward with every linear routed through the Pallas BWA kernel."""
    def linear(x, name):
        if name not in bwa:
            return x @ p[name].T
        planes, mu, shift = quantize_acts_jnp(x)
        bp = bwa[name]
        return bwa_linear(planes, mu, shift,
                          jnp.asarray(bp["qbits"]), jnp.asarray(bp["mbits"]),
                          jnp.asarray(bp["alpha"]), jnp.asarray(bp["beta"]),
                          jnp.asarray(bp["wsum"]), group_size=group_size,
                          row_tile=_row_tile(bp["qbits"].shape[0]))

    p = {k: jnp.asarray(v) for k, v in p.items()}
    x = p["embed"][tokens]
    for l in range(cfg["n_layers"]):
        x = _block(cfg, p, l, x, linear)
    x = rmsnorm(x, p["final_norm"], cfg["rmsnorm_eps"])
    return x @ p["lm_head"].T


def quantize_acts_jnp(x):
    """Traceable INT4 -> planes quantization (jnp version of
    kernels.ref.quantize_acts_int4)."""
    lo = jnp.minimum(x.min(axis=1), 0.0)
    hi = jnp.maximum(x.max(axis=1), 0.0)
    scale = jnp.where(hi - lo > 0, (hi - lo) / 15.0, 1.0)
    zero = jnp.clip(jnp.round(-lo / scale), 0, 15)
    q = jnp.clip(jnp.round(x / scale[:, None]) + zero[:, None], 0, 15)
    q = q.astype(jnp.int32)
    planes = jnp.stack([(q >> a) & 1 for a in range(4)], axis=1)
    planes = planes.astype(jnp.float32)
    mu = scale[:, None] * (2.0 ** jnp.arange(4))[None, :]
    shift = -scale * zero
    return planes, mu, shift.astype(jnp.float32)
