"""Build-time trainer: produces the tiny model checkpoints the Rust layer
quantizes and evaluates (DESIGN.md substitution for LLaMA/Vicuna weights).

Models (name -> config kind, corpus mix, seed):
  llama1-7b   tiny      wiki                 1
  llama2-7b   tiny      wiki + c4            2
  vicuna-7b   tiny      c4-heavy mix         3
  llama1-13b  tiny-13b  wiki                 4
  llama2-13b  tiny-13b  wiki + c4            5
  vicuna-13b  tiny-13b  c4-heavy mix         6

Training is plain AdamW on next-token cross entropy over the Rust-generated
corpora in artifacts/data/. Loss curves land next to each checkpoint as
<name>_loss.json and are summarized in EXPERIMENTS.md.
"""

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import common, model

MODEL_ZOO = {
    "llama1-7b": ("tiny", {"wiki": 1.0}, 1),
    "llama2-7b": ("tiny", {"wiki": 0.7, "c4": 0.3}, 2),
    "vicuna-7b": ("tiny", {"wiki": 0.4, "c4": 0.6}, 3),
    "llama1-13b": ("tiny-13b", {"wiki": 1.0}, 4),
    "llama2-13b": ("tiny-13b", {"wiki": 0.7, "c4": 0.3}, 5),
    "vicuna-13b": ("tiny-13b", {"wiki": 0.4, "c4": 0.6}, 6),
}


def batches(streams, mix, batch, seq, steps, seed):
    """Yield [batch, seq+1] windows sampled from the corpus mix."""
    rng = np.random.default_rng(seed)
    names = sorted(mix)
    probs = np.array([mix[n] for n in names])
    probs = probs / probs.sum()
    for _ in range(steps):
        rows = []
        for _ in range(batch):
            src = streams[names[rng.choice(len(names), p=probs)]]
            start = rng.integers(0, len(src) - seq - 1)
            rows.append(src[start : start + seq + 1])
        yield np.stack(rows)


def adamw_init(p):
    z = lambda: {k: np.zeros_like(v) for k, v in p.items()}
    return {"m": z(), "v": z(), "t": 0}


def train_one(name, data_dir, out_dir, steps, batch, seq, lr):
    kind, mix, seed = MODEL_ZOO[name]
    cfg = common.config_for(kind)
    cfg["name"] = name
    seq = min(seq, cfg["max_seq"] - 1)
    streams = {
        flavor: common.load_tokens(Path(data_dir) / f"{flavor}_train.tok")
        for flavor in mix
    }
    params = model.init_params(cfg, seed)

    loss_grad = jax.jit(
        jax.value_and_grad(lambda p, b: model.loss_fn(cfg, p, b))
    )

    opt = adamw_init(params)
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.01
    curve = []
    t0 = time.time()
    for step, b in enumerate(
        batches(streams, mix, batch, seq, steps, seed * 7919)
    ):
        loss, g = loss_grad(params, jnp.asarray(b))
        opt["t"] += 1
        t = opt["t"]
        # cosine decay with short warmup
        warm = min(1.0, t / 20.0)
        decay = 0.5 * (1 + np.cos(np.pi * min(1.0, t / steps)))
        lr_t = lr * warm * (0.1 + 0.9 * decay)
        for k in params:
            gk = np.asarray(g[k])
            opt["m"][k] = b1 * opt["m"][k] + (1 - b1) * gk
            opt["v"][k] = b2 * opt["v"][k] + (1 - b2) * gk * gk
            mhat = opt["m"][k] / (1 - b1**t)
            vhat = opt["v"][k] / (1 - b2**t)
            params[k] = np.asarray(params[k]) * (1 - lr_t * wd) - lr_t * (
                mhat / (np.sqrt(vhat) + eps)
            )
        curve.append(float(loss))
        if step % 25 == 0 or step == steps - 1:
            print(
                f"[{name}] step {step:4d} loss {float(loss):.4f} "
                f"lr {lr_t:.2e} ({time.time()-t0:.0f}s)",
                flush=True,
            )

    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    ckpt = out_dir / f"{name}.bin"
    common.save_checkpoint(ckpt, cfg, params)
    (out_dir / f"{name}_loss.json").write_text(
        json.dumps({"name": name, "steps": steps, "loss": curve})
    )
    print(f"[{name}] wrote {ckpt} (final loss {curve[-1]:.4f})")
    return curve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", default="../artifacts/data")
    ap.add_argument("--out", default="../artifacts/models")
    ap.add_argument("--models", default="all", help="comma list or 'all'")
    ap.add_argument("--steps", type=int, default=260)
    ap.add_argument("--steps-13b", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    names = (
        list(MODEL_ZOO) if args.models == "all" else args.models.split(",")
    )
    for name in names:
        kind = MODEL_ZOO[name][0]
        steps = args.steps_13b if kind.endswith("13b") else args.steps
        train_one(name, args.data, args.out, steps, args.batch, args.seq,
                  args.lr)


if __name__ == "__main__":
    main()
