"""Shared helpers for the build-time Python layer: token-file IO and the
BWACKPT1 checkpoint format (both defined by the Rust side — see
rust/src/data/mod.rs and rust/src/model/checkpoint.rs)."""

import json
import struct
from pathlib import Path

import numpy as np

TOK_MAGIC = b"BWATOK1\x00"
CKPT_MAGIC = b"BWACKPT1"


def load_tokens(path):
    """Read a BWATOK1 token stream as a uint16 numpy array."""
    data = Path(path).read_bytes()
    assert data[:8] == TOK_MAGIC, f"bad magic in {path}"
    (n,) = struct.unpack("<Q", data[8:16])
    toks = np.frombuffer(data[16:], dtype="<u2")
    assert len(toks) == n, f"token count mismatch in {path}"
    return toks.astype(np.int32)


def save_checkpoint(path, config: dict, tensors: dict):
    """Write a BWACKPT1 checkpoint the Rust runtime can load.

    `tensors` maps name -> float32 numpy array. Entries are written in
    sorted-name order (matching Rust's BTreeMap iteration order)."""
    names = sorted(tensors)
    entries = []
    offset = 0
    for name in names:
        arr = np.ascontiguousarray(tensors[name], dtype=np.float32)
        entries.append(
            {"name": name, "shape": list(arr.shape), "offset": offset}
        )
        offset += arr.size
    header = json.dumps({"config": config, "tensors": entries}).encode()
    with open(path, "wb") as f:
        f.write(CKPT_MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for name in names:
            f.write(np.ascontiguousarray(tensors[name], dtype="<f4").tobytes())


def load_checkpoint(path):
    """Read a BWACKPT1 checkpoint back (for tests / AOT param feeding)."""
    data = Path(path).read_bytes()
    assert data[:8] == CKPT_MAGIC, f"bad magic in {path}"
    (hlen,) = struct.unpack("<I", data[8:12])
    header = json.loads(data[12 : 12 + hlen])
    payload = np.frombuffer(data[12 + hlen :], dtype="<f4")
    tensors = {}
    for e in header["tensors"]:
        n = int(np.prod(e["shape"])) if e["shape"] else 1
        tensors[e["name"]] = (
            payload[e["offset"] : e["offset"] + n].reshape(e["shape"]).copy()
        )
    return header["config"], tensors


# Model configs — mirror rust/src/model/config.rs exactly.
TINY = {
    "name": "tiny",
    "vocab_size": 512,
    "d_model": 192,
    "n_layers": 3,
    "n_heads": 3,
    "d_ff": 512,
    "max_seq": 160,
    "rope_theta": 10000.0,
    "rmsnorm_eps": 1e-5,
}

TINY_13B = {
    **TINY,
    "name": "tiny-13b",
    "d_model": 256,
    "n_layers": 4,
    "n_heads": 4,
    "d_ff": 640,
}


def config_for(kind: str) -> dict:
    return dict(TINY_13B if kind.endswith("13b") else TINY)
